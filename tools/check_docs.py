"""Docs consistency checker (the CI `docs` job; runnable locally).

    python tools/check_docs.py

Two guarantees:

1. Every *relative* markdown link in the repo's ``*.md`` files resolves to an
   existing file or directory (anchors are stripped; absolute URLs and
   mailto: are ignored).
2. README.md quotes the exact tier-1 verify command ROADMAP.md declares, so
   the front-door instructions can never drift from the contract the driver
   enforces.

Exit status 0 on success; 1 with a per-problem report otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".github", ".claude", "__pycache__", ".pytest_cache"}

# [text](target) — images match the same way; target may contain spaces, be
# <>-wrapped, or carry a quoted title, all unpacked in _link_target
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def _link_target(raw: str) -> str:
    raw = raw.strip()
    if raw.startswith("<") and ">" in raw:          # [x](<path with spaces>)
        raw = raw[1:raw.index(">")]
    else:
        m = re.match(r'(\S+)\s+"[^"]*"$', raw)      # [x](path "title")
        if m:
            raw = m.group(1)
    return raw.split("#", 1)[0]


def md_files():
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.relative_to(REPO).parts):
            yield p


def check_links() -> list:
    problems = []
    for md in md_files():
        for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
            target = _link_target(m.group(1))
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                problems.append(f"{md.relative_to(REPO)}: broken link → {m.group(1)}")
    return problems


def check_verify_command() -> list:
    roadmap = (REPO / "ROADMAP.md").read_text(encoding="utf-8")
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", roadmap)
    if not m:
        return ["ROADMAP.md: no '**Tier-1 verify:** `...`' line found"]
    cmd = m.group(1)
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    if cmd not in readme:
        return [f"README.md: tier-1 verify command drifted from ROADMAP.md "
                f"(expected to contain: {cmd})"]
    return []


def main() -> int:
    problems = check_links() + check_verify_command()
    for p in problems:
        print(f"FAIL {p}")
    n_md = sum(1 for _ in md_files())
    if problems:
        print(f"{len(problems)} problem(s) across {n_md} markdown files")
        return 1
    print(f"ok: {n_md} markdown files, links resolve, verify command in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
