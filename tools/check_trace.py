#!/usr/bin/env python3
"""Validate observability artifacts (docs/observability.md) — stdlib only.

    python tools/check_trace.py trace.json [--metrics metrics.prom]

Trace checks (Chrome trace-event JSON, the format serve.py --trace writes):

* envelope: ``{"traceEvents": [...]}`` with a list of event records
* every record has a known ``ph`` and the fields that phase requires
  (``X`` → non-negative ``dur``; ``C`` → numeric ``args.value``; ``M`` →
  a recognised metadata name)
* every (pid, tid) that carries events has ``thread_name`` metadata
* ``B``/``E`` events balance per (pid, tid) — every begin is closed by a
  matching end, never cross-nested
* ``X`` spans on one (pid, tid) track nest properly — a span either
  contains or is disjoint from every other span on its track (partial
  overlap means the emitter timed overlapping phases, which would
  double-count wall time)
* timestamps are non-negative and finite
* prefix-cache instants carry well-formed args: ``prefix_hit`` needs
  positive numeric ``tokens``/``blocks``, ``prefix_miss`` numeric
  ``tokens``, and ``cow`` numeric ``block``/``copy`` with
  ``block != copy`` (a block can never be its own COW copy)

Metrics checks (Prometheus text exposition format):

* every sample line parses as ``name{labels} value`` with a valid metric
  name and a finite value
* every sample belongs to a preceding ``# TYPE`` block
* histograms are internally consistent: bucket counts are cumulative
  (non-decreasing as ``le`` ascends), the ``+Inf`` bucket equals
  ``_count``, and ``_sum`` / ``_count`` are both present
* the ``serve_prefix_cache_*`` family is all-or-nothing (a registry that
  exports one of the six instruments must export them all) and
  self-consistent: zero hits cannot coexist with nonzero hit tokens,
  and no member may be negative
* the ``serve_pool_*`` family is likewise all-or-nothing and
  self-consistent: ``serve_pool_quantized`` must be exactly 0 or 1,
  ``serve_pool_bytes_per_token`` must be positive, and no member may
  be negative
* the ``serve_sparse_*`` family (sparse block-top-k decode) is
  all-or-nothing — dense runs export none of it, sparse runs export all
  six instruments (``serve_sparse_selected_blocks`` is a histogram, so
  its ``_bucket``/``_sum``/``_count`` samples count) — non-negative,
  with ``serve_sparse_topk`` positive and selected blocks never
  exceeding candidate blocks; ``sparse_select`` instants need numeric
  ``selected``/``candidate`` args
* the name-encoded ``serve_replica_{i}_*`` family (the router's
  per-replica instruments — the registry has no labels by design) is
  all-or-nothing across BOTH dimensions: replica ids must be contiguous
  from 0, every id must export every suffix, no member may be negative,
  and the per-replica ``submitted_total`` / ``completed_total`` must sum
  to the fleet-wide ``serve_requests_{submitted,completed}_total``

Exit status 0 and a one-line summary on success; every violation is
printed and the exit status is 1.  CI's ``obs`` job runs this against a
freshly traced serve run.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
from collections import defaultdict
from pathlib import Path

ERRORS: list = []

_PH_KNOWN = frozenset("XBEiCM")
_META_NAMES = frozenset({"process_name", "process_labels",
                         "process_sort_index", "thread_name",
                         "thread_sort_index"})
_METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
#: required numeric args per prefix-cache instant (serve_loop/core.cache emit)
_CACHE_INSTANT_ARGS = {"prefix_hit": ("tokens", "blocks"),
                       "prefix_miss": ("tokens",),
                       "cow": ("block", "copy"),
                       "sparse_select": ("selected", "candidate")}
#: the complete serve_prefix_cache_* instrument family — all-or-nothing
_PC_FAMILY = ("serve_prefix_cache_hits_total",
              "serve_prefix_cache_misses_total",
              "serve_prefix_cache_hit_tokens_total",
              "serve_prefix_cache_cow_total",
              "serve_prefix_cache_blocks_retained",
              "serve_prefix_cache_blocks_cached")
#: the complete serve_pool_* instrument family — all-or-nothing
_POOL_FAMILY = ("serve_pool_blocks_used",
                "serve_pool_quantized",
                "serve_pool_bytes_per_token",
                "serve_pool_allocated_bytes")
#: the complete serve_sparse_* instrument family — all-or-nothing (absent
#: entirely in dense runs; serve_sparse_selected_blocks is a histogram, so
#: its _bucket/_sum/_count samples belong to the family too)
_SPARSE_FAMILY = ("serve_sparse_topk",
                  "serve_sparse_recent",
                  "serve_sparse_steps_total",
                  "serve_sparse_selected_blocks_total",
                  "serve_sparse_candidate_blocks_total",
                  "serve_sparse_selected_blocks")
#: per-replica suffixes the router exports for EVERY replica id
#: (mirrors runtime/router.py::REPLICA_METRIC_SUFFIXES)
_REPLICA_SUFFIXES = ("submitted_total", "completed_total", "waiting",
                     "resident", "blocks_used")
_REPLICA_RE = re.compile(r"^serve_replica_(\d+)_([a-z_]+)$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)(?:\s+\d+)?$")


def err(msg: str) -> None:
    ERRORS.append(msg)
    print(f"FAIL: {msg}")


def _num(v) -> bool:
    return isinstance(v, (int, float)) and math.isfinite(v) \
        and not isinstance(v, bool)


# ---------------------------------------------------------------------------
# trace-event JSON
# ---------------------------------------------------------------------------

def check_trace(path: Path) -> int:
    try:
        data = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        err(f"{path}: unreadable or invalid JSON ({e})")
        return 0
    if not isinstance(data, dict) or not isinstance(
            data.get("traceEvents"), list):
        err(f"{path}: missing traceEvents list envelope")
        return 0
    events = data["traceEvents"]

    named_tids = set()                       # (pid, tid) with thread_name
    used_tids = set()                        # (pid, tid) carrying events
    be_stacks = defaultdict(list)            # (pid, tid) -> open B names
    x_spans = defaultdict(list)              # (pid, tid) -> (start, end, name)
    for i, e in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(e, dict):
            err(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PH_KNOWN:
            err(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            err(f"{where}: missing name")
            continue
        key = (e.get("pid"), e.get("tid"))
        if ph == "M":
            if e["name"] not in _META_NAMES:
                err(f"{where}: unknown metadata name {e['name']!r}")
            if e["name"] == "thread_name":
                named_tids.add(key)
            continue
        ts = e.get("ts")
        if not _num(ts) or ts < 0:
            err(f"{where}: bad ts {ts!r}")
            continue
        used_tids.add(key)
        if ph == "X":
            dur = e.get("dur")
            if not _num(dur) or dur < 0:
                err(f"{where}: X span with bad dur {dur!r}")
                continue
            x_spans[key].append((ts, ts + dur, e["name"]))
        elif ph == "B":
            be_stacks[key].append(e["name"])
        elif ph == "E":
            stack = be_stacks[key]
            if not stack:
                err(f"{where}: E {e['name']!r} with no open B on tid {key}")
            elif stack[-1] != e["name"]:
                err(f"{where}: E {e['name']!r} cross-nests open B "
                    f"{stack[-1]!r} on tid {key}")
                stack.pop()
            else:
                stack.pop()
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not _num(args.get("value")):
                err(f"{where}: counter without numeric args.value")
        elif ph == "i":
            if e.get("s", "t") not in ("g", "p", "t"):
                err(f"{where}: instant with bad scope {e.get('s')!r}")
            spec = _CACHE_INSTANT_ARGS.get(e["name"])
            if spec is not None:
                iargs = e.get("args") if isinstance(e.get("args"), dict) else {}
                for field in spec:
                    if not _num(iargs.get(field)):
                        err(f"{where}: {e['name']} instant missing numeric "
                            f"args.{field}")
                if e["name"] == "prefix_hit" and \
                        _num(iargs.get("tokens")) and iargs["tokens"] <= 0:
                    err(f"{where}: prefix_hit with non-positive tokens "
                        f"{iargs['tokens']!r}")
                if e["name"] == "cow" and _num(iargs.get("block")) and \
                        iargs.get("block") == iargs.get("copy"):
                    err(f"{where}: cow instant copies block "
                        f"{iargs['block']!r} onto itself")

    for key, stack in sorted(be_stacks.items()):
        if stack:
            err(f"{path}: tid {key} ends with unclosed B events {stack} "
                f"(every begin needs a matching end)")
    for key in sorted(used_tids - named_tids):
        err(f"{path}: tid {key} carries events but has no thread_name "
            f"metadata")

    # X proper nesting per track: sweep spans sorted by (start, -end); each
    # span must be contained by or disjoint from every enclosing span.
    for key, spans in sorted(x_spans.items()):
        stack = []                           # (start, end, name) enclosing
        for start, end, name in sorted(spans, key=lambda s: (s[0], -s[1])):
            while stack and stack[-1][1] <= start:
                stack.pop()
            if stack and end > stack[-1][1]:
                err(f"{path}: span {name!r} [{start:.1f},{end:.1f}] on tid "
                    f"{key} partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]:.1f},{stack[-1][1]:.1f}]")
                continue
            stack.append((start, end, name))
    return len(events)


# ---------------------------------------------------------------------------
# Prometheus text format
# ---------------------------------------------------------------------------

def _parse_value(s: str):
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    try:
        return float(s)
    except ValueError:
        return None


def check_metrics(path: Path) -> int:
    try:
        text = path.read_text(encoding="utf-8")
    except OSError as e:
        err(f"{path}: unreadable ({e})")
        return 0
    types: dict = {}
    samples = []                             # (name, labels-dict, value)
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                err(f"{path}:{ln}: malformed TYPE line {line!r}")
                continue
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue                         # HELP / comments
        m = _SAMPLE_RE.match(line)
        if not m:
            err(f"{path}:{ln}: unparseable sample line {line!r}")
            continue
        value = _parse_value(m.group("value"))
        if value is None or (value != value):
            err(f"{path}:{ln}: bad sample value {m.group('value')!r}")
            continue
        labels = {}
        for item in filter(None, (m.group("labels") or "").split(",")):
            if "=" not in item:
                err(f"{path}:{ln}: malformed label {item!r}")
                continue
            k, _, v = item.partition("=")
            labels[k.strip()] = v.strip().strip('"')
        samples.append((m.group("name"), labels, value))

    by_name = defaultdict(list)
    for name, labels, value in samples:
        base = re.sub(r"_(bucket|sum|count)$", "", name) \
            if re.search(r"_(bucket|sum|count)$", name) else name
        owner = base if base in types else name
        if owner not in types:
            err(f"{path}: sample {name!r} has no # TYPE block")
            continue
        by_name[owner].append((name, labels, value))

    for owner, rows in sorted(by_name.items()):
        if types.get(owner) != "histogram":
            continue
        buckets = sorted(
            ((math.inf if r[1]["le"] == "+Inf" else float(r[1]["le"]), r[2])
             for r in rows if r[0] == f"{owner}_bucket" and "le" in r[1]),
            key=lambda t: t[0])
        count = next((r[2] for r in rows if r[0] == f"{owner}_count"), None)
        has_sum = any(r[0] == f"{owner}_sum" for r in rows)
        if not buckets or buckets[-1][0] != math.inf:
            err(f"{path}: histogram {owner} missing +Inf bucket")
            continue
        if count is None or not has_sum:
            err(f"{path}: histogram {owner} missing _count or _sum")
            continue
        cum = [c for _, c in buckets]
        if any(b > a for a, b in zip(cum[1:], cum)):
            err(f"{path}: histogram {owner} buckets not cumulative: {cum}")
        if buckets[-1][1] != count:
            err(f"{path}: histogram {owner} +Inf bucket {buckets[-1][1]} "
                f"!= _count {count}")

    # serve_prefix_cache_* family: all-or-nothing and self-consistent
    pc_vals = {n: v for n, _, v in samples if n in _PC_FAMILY}
    stray = sorted(n for n, _, _ in samples
                   if n.startswith("serve_prefix_cache_")
                   and n not in _PC_FAMILY)
    for n in stray:
        err(f"{path}: unknown serve_prefix_cache_* instrument {n!r}")
    if pc_vals:
        for n in _PC_FAMILY:
            if n not in pc_vals:
                err(f"{path}: serve_prefix_cache_* family incomplete — "
                    f"missing {n}")
        for n, v in sorted(pc_vals.items()):
            if v < 0:
                err(f"{path}: {n} is negative ({v})")
        if pc_vals.get("serve_prefix_cache_hits_total") == 0 and \
                pc_vals.get("serve_prefix_cache_hit_tokens_total", 0) > 0:
            err(f"{path}: hit_tokens_total > 0 with hits_total == 0")

    # serve_pool_* family: all-or-nothing and self-consistent
    pool_vals = {n: v for n, _, v in samples if n in _POOL_FAMILY}
    for n in sorted(n for n, _, _ in samples
                    if n.startswith("serve_pool_") and n not in _POOL_FAMILY):
        err(f"{path}: unknown serve_pool_* instrument {n!r}")
    if pool_vals:
        for n in _POOL_FAMILY:
            if n not in pool_vals:
                err(f"{path}: serve_pool_* family incomplete — missing {n}")
        for n, v in sorted(pool_vals.items()):
            if v < 0:
                err(f"{path}: {n} is negative ({v})")
        q = pool_vals.get("serve_pool_quantized")
        if q is not None and q not in (0.0, 1.0):
            err(f"{path}: serve_pool_quantized must be 0 or 1, got {q}")
        bpt = pool_vals.get("serve_pool_bytes_per_token")
        if bpt is not None and bpt <= 0:
            err(f"{path}: serve_pool_bytes_per_token must be positive, "
                f"got {bpt}")

    # serve_sparse_* family: all-or-nothing and self-consistent
    def _sparse_base(n):
        return re.sub(r"_(bucket|sum|count)$", "", n) \
            if n.startswith("serve_sparse_selected_blocks_") else n
    sparse_vals = {n: v for n, _, v in samples
                   if n in _SPARSE_FAMILY and types.get(n) != "histogram"}
    sparse_seen = {_sparse_base(n) for n, _, _ in samples
                   if n.startswith("serve_sparse_")}
    for n in sorted(sparse_seen - set(_SPARSE_FAMILY)):
        err(f"{path}: unknown serve_sparse_* instrument {n!r}")
    if sparse_seen:
        for n in _SPARSE_FAMILY:
            if n not in sparse_seen:
                err(f"{path}: serve_sparse_* family incomplete — missing {n}")
        for n, v in sorted(sparse_vals.items()):
            if v < 0:
                err(f"{path}: {n} is negative ({v})")
        if sparse_vals.get("serve_sparse_topk", 1) <= 0:
            err(f"{path}: serve_sparse_topk must be positive when the "
                f"sparse family is exported")
        sel = sparse_vals.get("serve_sparse_selected_blocks_total")
        cand = sparse_vals.get("serve_sparse_candidate_blocks_total")
        if sel is not None and cand is not None and sel > cand:
            err(f"{path}: sparse selected blocks ({sel}) exceed candidate "
                f"blocks ({cand})")

    # serve_replica_{i}_* family: all-or-nothing over ids × suffixes
    replica = {}                             # (id, suffix) -> value
    for n, _, v in samples:
        if not n.startswith("serve_replica_"):
            continue
        m = _REPLICA_RE.match(n)
        if not m or m.group(2) not in _REPLICA_SUFFIXES:
            err(f"{path}: unknown serve_replica_* instrument {n!r}")
            continue
        replica[(int(m.group(1)), m.group(2))] = v
    if replica:
        ids = sorted({i for i, _ in replica})
        if ids != list(range(len(ids))):
            err(f"{path}: serve_replica_* ids not contiguous from 0: {ids}")
        for i in ids:
            for suffix in _REPLICA_SUFFIXES:
                if (i, suffix) not in replica:
                    err(f"{path}: serve_replica_* family incomplete — "
                        f"replica {i} missing {suffix}")
        for (i, suffix), v in sorted(replica.items()):
            if v < 0:
                err(f"{path}: serve_replica_{i}_{suffix} is negative ({v})")
        globals_ = {n: v for n, _, v in samples
                    if n in ("serve_requests_submitted_total",
                             "serve_requests_completed_total")}
        for suffix, gname in (("submitted_total",
                               "serve_requests_submitted_total"),
                              ("completed_total",
                               "serve_requests_completed_total")):
            total = sum(v for (i, s), v in replica.items() if s == suffix)
            if gname in globals_ and total != globals_[gname]:
                err(f"{path}: sum of serve_replica_*_{suffix} ({total}) != "
                    f"{gname} ({globals_[gname]})")
    return len(samples)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default="",
                    help="also validate this Prometheus text-format file")
    args = ap.parse_args(argv)

    ERRORS.clear()                           # fresh verdict per invocation
    n_events = check_trace(Path(args.trace))
    summary = f"{args.trace}: {n_events} events"
    if args.metrics:
        n_samples = check_metrics(Path(args.metrics))
        summary += f"; {args.metrics}: {n_samples} samples"
    if ERRORS:
        print(f"{len(ERRORS)} violation(s) — {summary}")
        return 1
    print(f"OK — {summary}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
