"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything (CSV)
    PYTHONPATH=src python -m benchmarks.run table1     # one table

Paper-scale experiments (LLaMA2-7B, RefinedWeb, lm-eval) are out of reach on
one CPU core; every benchmark reproduces the corresponding table's *mechanism*
at miniature scale with held-out synthetic perplexity as the metric, and the
orderings the paper reports are asserted in the derived column.

Output rows: ``name,us_per_call,derived``.

``serving`` additionally writes ``BENCH_serving.json`` at the repo root —
one structured row per scenario (throughput, TTFT percentiles, occupancy,
acceptance, phase breakdown) for machine consumption; docs/observability.md
documents the schema.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.base import EliteKVConfig
from repro.core import convert, lrd, ropelite
from repro.core.cache import cache_ratio, model_cache_floats_per_token
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import lm
from repro.runtime import train_loop

ROWS = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# shared miniature setup
# ---------------------------------------------------------------------------

def _base_cfg():
    return get_config("llama2_7b").reduced(
        num_layers=2, d_model=96, n_heads=8, n_kv_heads=8, d_head=16,
        d_ff=256, vocab_size=256)


def _data(cfg, seed, batch=8, seq=48):
    return TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    batch_size=batch, seed=seed))


def _eval_ppl(params, buffers, cfg, seed=991, batches=3):
    d = _data(cfg, seed, batch=4)
    tot = 0.0
    for _ in range(batches):
        tot += float(lm.loss_fn(params, buffers, cfg, next(d))[0])
    return float(np.exp(tot / batches))


_PRETRAINED = {}


def pretrained(steps=240):
    if "m" not in _PRETRAINED:
        cfg = _base_cfg()
        params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
        tc = train_loop.TrainConfig(lr=3e-3)
        params, _, _ = train_loop.train(params, buffers, cfg, tc,
                                        iter(_data(cfg, 0)), steps, log_every=0)
        _PRETRAINED["m"] = (params, buffers, cfg)
    return _PRETRAINED["m"]


def _uptrain(params, buffers, cfg, steps=120, lr=1e-3, data=None):
    tc = train_loop.TrainConfig(lr=lr)
    params, _, _ = train_loop.train(params, buffers, cfg, tc,
                                    data or iter(_data(cfg, 1)), steps,
                                    log_every=0)
    return params


def _elite_at_ratio(params, buffers, cfg, ratio, method="greedy",
                    lrd_kind="joint", r=None):
    full = 2 * cfg.n_kv_heads * cfg.head_dim
    budget = int(ratio * full)
    if r is None:
        r = max(1, min(budget // (4 * cfg.n_kv_heads), cfg.head_dim // 2 - 1))
    rest = max(8, budget - 2 * r * cfg.n_kv_heads)
    ek = EliteKVConfig(enabled=True, elite_r=r, d_ckv=rest, lrd=lrd_kind,
                       d_ck=max(4, rest // 2), d_cv=max(4, rest - rest // 2))
    calib = next(_data(cfg, 77, batch=2))
    return convert.elitekv_from_baseline(
        params, buffers, cfg, {"tokens": calib["tokens"]}, ek, method=method)


# ---------------------------------------------------------------------------
# paper Table 1: EliteKV vs GQA across cache ratios
# ---------------------------------------------------------------------------

def table1():
    params, buffers, cfg = pretrained()
    base_ppl = _eval_ppl(params, buffers, cfg)
    emit("table1/baseline", 0, f"ppl={base_ppl:.2f};cache=1.000")
    for ratio, n_kv in [(0.5, 4), (0.25, 2), (0.125, 1)]:
        t0 = time.time()
        # GQA mean-pool baseline (Ainslie) at the same cache ratio
        gp, gcfg = convert.to_gqa(params, cfg, n_kv)
        gp = _uptrain(gp, buffers, gcfg)
        gqa_ppl = _eval_ppl(gp, buffers, gcfg)
        # EliteKV at the same ratio
        ep, eb, ecfg = _elite_at_ratio(params, buffers, cfg, ratio)
        ep = _uptrain(ep, eb, ecfg)
        e_ppl = _eval_ppl(ep, eb, ecfg)
        win = "elitekv" if e_ppl <= gqa_ppl else "gqa"
        emit(f"table1/ratio_{ratio}", (time.time() - t0) * 1e6,
             f"gqa_ppl={gqa_ppl:.2f};elitekv_ppl={e_ppl:.2f};"
             f"ratio={cache_ratio(ecfg, cfg):.3f};winner={win}")


# ---------------------------------------------------------------------------
# paper Table 2: Uniform vs Contribution vs RoPElite
# ---------------------------------------------------------------------------

def table2():
    params, buffers, cfg = pretrained()
    for r in (4, 2):
        res = {}
        t0 = time.time()
        for method in ("uniform", "contribution", "greedy"):
            ep, eb, ecfg = _elite_at_ratio(params, buffers, cfg, 0.5,
                                           method=method, r=r)
            ep = _uptrain(ep, eb, ecfg, steps=80)
            res[method] = _eval_ppl(ep, eb, ecfg)
        order = sorted(res, key=res.get)
        emit(f"table2/r_{r}", (time.time() - t0) * 1e6,
             ";".join(f"{m}={res[m]:.2f}" for m in res) + f";best={order[0]}")


# ---------------------------------------------------------------------------
# paper Fig. 5: S-LRD vs J-LRD at matched cache size
# ---------------------------------------------------------------------------

def fig5():
    params, buffers, cfg = pretrained()
    for ratio in (0.5, 0.25):
        t0 = time.time()
        ppls = {}
        for kind in ("joint", "separate"):
            ep, eb, ecfg = _elite_at_ratio(params, buffers, cfg, ratio,
                                           lrd_kind=kind)
            ppls[kind] = _eval_ppl(ep, eb, ecfg)   # conversion ppl, no uptrain
        emit(f"fig5/ratio_{ratio}", (time.time() - t0) * 1e6,
             f"jlrd_ppl={ppls['joint']:.2f};slrd_ppl={ppls['separate']:.2f};"
             f"jlrd_wins={ppls['joint'] <= ppls['separate']}")


# ---------------------------------------------------------------------------
# paper Fig. 6: recovery speed vs cache ratio
# ---------------------------------------------------------------------------

def fig6():
    params, buffers, cfg = pretrained()
    base_ppl = _eval_ppl(params, buffers, cfg)
    for ratio in (0.5, 0.25, 0.125):
        ep, eb, ecfg = _elite_at_ratio(params, buffers, cfg, ratio)
        curve = [_eval_ppl(ep, eb, ecfg)]
        t0 = time.time()
        stream = iter(_data(ecfg, 1))   # ONE continuing stream across rounds
        for _ in range(3):
            ep = _uptrain(ep, eb, ecfg, steps=40, data=stream)
            curve.append(_eval_ppl(ep, eb, ecfg))
        emit(f"fig6/ratio_{ratio}", (time.time() - t0) * 1e6,
             "curve=" + "|".join(f"{p:.2f}" for p in curve)
             + f";base={base_ppl:.2f}")


# ---------------------------------------------------------------------------
# kernel micro-bench (interpret-mode correctness + XLA-path wall time on CPU)
# ---------------------------------------------------------------------------

def kernels():
    from repro.kernels import ref as kref
    key = jax.random.PRNGKey(0)
    B, nkv, G, r2, dc, S = 4, 4, 4, 16, 128, 1024
    nh = nkv * G
    ks = jax.random.split(key, 4)
    q_e = jax.random.normal(ks[0], (B, nh, r2), jnp.float32)
    q_lat = jax.random.normal(ks[1], (B, nh, dc), jnp.float32)
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2), jnp.float32)
    c = jax.random.normal(ks[3], (B, S, dc), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)

    f_ref = jax.jit(lambda *a: kref.elite_decode_ref(*a, q_group=G, scale=0.1))
    f_ref(q_e, q_lat, k_e, c, c, lengths).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        f_ref(q_e, q_lat, k_e, c, c, lengths).block_until_ready()
    us = (time.time() - t0) / 10 * 1e6
    # bytes actually read per call from the compressed cache:
    comp_bytes = B * S * (nkv * r2 + dc) * 4
    # what an UNcompressed GQA cache read would have been (dh=32, k+v):
    full_bytes = B * S * (2 * nkv * 32) * 4 * 4
    emit("kernels/elite_decode_xla", us,
         f"cache_bytes={comp_bytes};baseline_bytes={full_bytes};"
         f"hbm_read_ratio={comp_bytes / full_bytes:.3f}")

    # baseline full-KV decode attention for wall-clock comparison (CPU)
    dh = 32
    kf = jax.random.normal(ks[2], (B, S, nkv, dh), jnp.float32)
    vf = jax.random.normal(ks[3], (B, S, nkv, dh), jnp.float32)
    qf = jax.random.normal(ks[0], (B, 1, nh, dh), jnp.float32)
    from repro.models.attention import _attend
    f_base = jax.jit(lambda q, k, v: _attend(q, k, v, G, 0.1, q_offset=S - 1))
    f_base(qf, kf, vf).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        f_base(qf, kf, vf).block_until_ready()
    emit("kernels/baseline_decode_xla", (time.time() - t0) / 10 * 1e6,
         "full_kv_read")

    # pallas interpret-mode correctness spot check (slow — 1 call)
    from repro.kernels import elite_decode as ed
    t0 = time.time()
    o_k = ed.elite_decode(q_e[:1], q_lat[:1], k_e[:1, :128], c[:1, :128],
                          c[:1, :128], jnp.array([128], jnp.int32), G, 0.1,
                          block_s=64, interpret=True)
    o_r = kref.elite_decode_ref(q_e[:1], q_lat[:1], k_e[:1, :128], c[:1, :128],
                                c[:1, :128], jnp.array([128], jnp.int32), G, 0.1)
    err = float(jnp.max(jnp.abs(o_k - o_r)))
    emit("kernels/elite_decode_pallas_interpret", (time.time() - t0) * 1e6,
         f"max_err_vs_oracle={err:.2e}")


# ---------------------------------------------------------------------------
# cache accounting across the assigned architectures
# ---------------------------------------------------------------------------

def cache_table():
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.n_attn_layers == 0:
            emit(f"cache/{arch}", 0, "inapplicable=ssm_no_kv_cache")
            continue
        ek = convert.pick_dims(cfg, 0.25)
        ecfg = dataclasses.replace(cfg, elitekv=ek)
        full = model_cache_floats_per_token(cfg)
        comp = model_cache_floats_per_token(ecfg)
        emit(f"cache/{arch}", 0,
             f"r={ek.elite_r};d_ckv={ek.d_ckv};floats_tok={comp};"
             f"baseline={full};ratio={comp / full:.3f};"
             f"bytes_32k_ctx={comp * 2 * 32768 / 2**20:.0f}MiB")


# ---------------------------------------------------------------------------
# serving: continuous batching over the paged pool (the systems trajectory —
# measures request throughput, not lockstep decode)
# ---------------------------------------------------------------------------

def serving_workload(rate: float, vocab_size: int = 128, n: int = 12,
                     seed: int = 7, sample_seed: int = 1000,
                     temperature: float = 0.0, top_p: float = 1.0):
    """Deterministic serving workload: bimodal prompt lengths (short
    interactive requests racing long ones — the case chunked prefill exists
    for), Poisson arrivals, and **pinned per-request sample seeds**
    (``sample_seed + uid``) so every comparison row — chunked vs one-shot,
    watermark vs preempt, speculative vs plain — decodes the *identical*
    request set and is token-comparable.  Two calls with the same arguments
    return identical requests (regression-tested)."""
    from repro.runtime import serve_loop
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        sp = int(rng.integers(4, 9)) if i % 2 else int(rng.integers(24, 41))
        reqs.append(serve_loop.Request(
            uid=i,
            prompt=rng.integers(0, vocab_size, sp).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 17)), arrival=t,
            temperature=temperature, top_p=top_p, seed=sample_seed + i))
    return reqs


def shared_prefix_workload(vocab_size: int = 128, n: int = 10,
                           shared: int = 64, max_suffix: int = 4,
                           seed: int = 17, sample_seed: int = 2000,
                           temperature: float = 0.0):
    """Template traffic: 90% of the requests share a ``shared``-token system
    prefix (the miniature stand-in for the 512-token system prompts of real
    template-heavy serving) followed by a short unique suffix; one request
    (uid 5) carries an unrelated prompt and must miss.  Request 0 arrives
    alone and warms the cache; the rest arrive after its prefill has
    registered the prefix blocks.  Deterministic: two calls with the same
    arguments return identical requests."""
    from repro.runtime import serve_loop
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab_size, shared).astype(np.int32)
    reqs = []
    for i in range(n):
        if i == 5:                         # the 10% non-sharer
            prompt = rng.integers(0, vocab_size, 8).astype(np.int32)
        else:
            sfx = rng.integers(0, vocab_size,
                               int(rng.integers(2, max_suffix + 1))
                               ).astype(np.int32)
            prompt = np.concatenate([pre, sfx])
        reqs.append(serve_loop.Request(
            uid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(4, 17)),
            arrival=0.0 if i == 0 else float(12 + 2 * i),
            temperature=temperature, top_p=0.9, seed=sample_seed + i))
    return reqs


#: Structured serving rows accumulated by ``serving()`` and written to
#: ``BENCH_serving.json`` at the repo root (schema in docs/observability.md).
#: v2: rows carry ``pool_dtype``/``pool_bytes_per_token``, plus the
#: ``pool_capacity_*`` quantization scenario pair.
#: v3: multi-device ``sharded_dev*`` scaling rows — device_count/tp/dp,
#: per-replica occupancy, pool bytes/token/device, and an asserted
#: ``tokens_match_single_device`` (the sharded path is bit-preserving).
#: v4: long-context sparse decode rows (``longctx_dense`` /
#: ``longctx_sparse_k*``) at ~8x the context of every other row —
#: ``context_tokens``, steady-state decode tok/s, ``sparse_decode_speedup``,
#: and an asserted teacher-forced ``top1_agreement_vs_dense`` >= 0.95 at
#: the benchmark's k.
SERVING_SCHEMA_VERSION = 4


def _serving_row(scenario: str, rep, us: float, **extra):
    """One machine-readable scenario row from a ``ServeReport``."""
    row = dict(
        scenario=scenario,
        us_per_step=round(us, 1),
        tok_s=round(rep.tok_per_s, 2),
        ttft_ms_p50=round(rep.ttft_wall_p50_ms, 2),
        ttft_ms_p95=round(rep.ttft_wall_p95_ms, 2),
        step_ms_p50=round(rep.step_ms_p50, 2),
        step_ms_p95=round(rep.step_ms_p95, 2),
        occupancy=round(rep.mean_occupancy, 4),
        occupancy_retained=round(rep.mean_occupancy_retained, 4),
        completed=rep.completed,
        decode_steps=rep.decode_steps,
        decoded_tokens=rep.decoded_tokens,
        prefill_chunks=rep.prefill_chunks,
        preemptions=rep.preemptions,
        swap_outs=rep.swap_outs,
        blocks_high_water=rep.pool_high_water_blocks,
        blocks_naive=rep.naive_blocks,
        block_reuse=round(rep.block_reuse_ratio, 3),
        acceptance=round(rep.acceptance_rate, 4),
        tokens_per_forward=round(rep.tokens_per_forward, 3),
        phase_ms={k: round(v, 2) for k, v in rep.phase_ms.items()},
        step_wall_ms_total=round(rep.step_wall_ms_total, 2),
        pool_dtype=rep.pool_dtype,
        pool_bytes_per_token=rep.pool_bytes_per_token,
    )
    row.update(extra)
    return row


def write_serving_json(rows, path=None) -> Path:
    """Write the ``BENCH_serving.json`` artifact (repo root by default)."""
    path = Path(path) if path else Path(__file__).resolve().parent.parent \
        / "BENCH_serving.json"
    path.write_text(json.dumps(
        {"benchmark": "serving", "schema_version": SERVING_SCHEMA_VERSION,
         "generated_by": "PYTHONPATH=src python -m benchmarks.run serving",
         "rows": rows}, indent=2) + "\n", encoding="utf-8")
    return path


def serving():
    from repro.runtime import serve_loop

    cfg = get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=128)
    cfg = dataclasses.replace(
        cfg, elitekv=EliteKVConfig(enabled=True, elite_r=4, d_ckv=64))
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    json_rows = []

    def run_one(rate, chunk, num_blocks=96, admission="preempt",
                eviction="recompute", lanes=0, speculate=0, draft_rank=0):
        scfg = serve_loop.SchedulerConfig(
            max_slots=4, block_size=8, num_blocks=num_blocks,
            max_new_tokens=16, max_len=64, prefill_bucket=8,
            prefill_chunk_tokens=chunk, prefill_batch_lanes=lanes,
            admission=admission, eviction=eviction,
            speculate_k=speculate, draft_rank=draft_rank)
        sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
        t0 = time.time()
        rep = sched.run(serving_workload(rate, vocab_size=cfg.vocab_size))
        us = (time.time() - t0) * 1e6 / max(rep.decode_steps, 1)
        return sched, rep, us

    plain_baseline = None                  # (sched, rep, us) of bursty/chunk8,
    for rate, tag in [(2.0, "bursty"), (0.4, "trickle")]:  # reused below
        for chunk in (0, 8):               # one-shot admission vs chunked
            sched, rep, us = run_one(rate, chunk)
            if (rate, chunk) == (2.0, 8):
                plain_baseline = (sched, rep, us)
            buckets = ";".join(f"ttft_prompt_{k}={v:.1f}"
                               for k, v in rep.ttft_steps_by_bucket.items())
            json_rows.append(_serving_row(
                f"poisson_{tag}_chunk{chunk}", rep, us,
                rate=rate, prefill_chunk=chunk))
            emit(f"serving/poisson_{tag}_chunk{chunk}", us,
                 f"tok_s={rep.tok_per_s:.1f};ttft_steps={rep.ttft_steps_mean:.1f};"
                 f"{buckets};prefill_chunks={rep.prefill_chunks};"
                 f"prefill_batch={rep.mean_prefill_batch:.2f};"
                 f"occupancy={rep.mean_occupancy:.2f};"
                 f"step_ms_p50={rep.step_ms_p50:.1f};step_ms_p95={rep.step_ms_p95:.1f};"
                 f"peak_slots={rep.peak_slots};"
                 f"blocks_hw={rep.pool_high_water_blocks};"
                 f"blocks_naive={rep.naive_blocks};"
                 f"reuse={rep.block_reuse_ratio:.2f};"
                 f"paged_beats_naive={rep.pool_high_water_blocks < rep.naive_blocks}")

    # watermark vs preempt at half the watermark-required capacity: the
    # reservation policy needs worst-case blocks for every concurrently
    # resident request (max_slots × ceil(max_len / block_size)); at 50% of
    # that it stalls admission (low occupancy, empty slots) while the
    # preempting policy fills the pool and completes the same request set
    # with identical tokens.
    wm_required = 4 * (-(-64 // 8))        # max_slots × blocks per worst case
    small = wm_required // 2
    results = {}
    for admission, eviction in [("watermark", "recompute"),
                                ("preempt", "recompute"), ("preempt", "swap")]:
        sched, rep, us = run_one(2.0, 8, num_blocks=small,
                                 admission=admission, eviction=eviction)
        results[(admission, eviction)] = {
            r.uid: list(r.generated) for r in sched.finished}
        json_rows.append(_serving_row(
            f"pool{small}_{admission}_{eviction}", rep, us,
            admission=admission, eviction=eviction, num_blocks=small,
            tokens_match_watermark=(results[(admission, eviction)]
                                    == results[("watermark", "recompute")])))
        emit(f"serving/pool{small}_{admission}_{eviction}", us,
             f"completed={rep.completed};occupancy={rep.mean_occupancy:.2f};"
             f"peak_slots={rep.peak_slots};preemptions={rep.preemptions};"
             f"preempted_requests={rep.preempted_requests};"
             f"swaps={rep.swap_outs};ttft_steps={rep.ttft_steps_mean:.1f};"
             f"prefill_batch={rep.mean_prefill_batch:.2f};"
             f"tokens_match_watermark="
             f"{results[(admission, eviction)] == results[('watermark', 'recompute')]}")

    # speculative vs plain decode on the identical seeded greedy workload:
    # plain advances 1 token per lane per forward; draft/verify advances
    # 1 + accepted.  The draft rank is a top-singular-direction truncation of
    # the joint factors — on this random-init miniature the spectrum is
    # nearly flat, so useful ranks sit close to d_ckv (64); a converted/
    # uptrained model concentrates energy in far fewer directions (the
    # paper's premise).  Greedy streams must be token-identical to plain.
    plain_sched, plain_rep, plain_us = plain_baseline   # bursty/chunk8 run
    plain_toks = {r.uid: list(r.generated) for r in plain_sched.finished}
    json_rows.append(_serving_row("spec_plain", plain_rep, plain_us,
                                  speculate_k=0))
    emit("serving/spec_plain", plain_us,
         f"tok_per_forward={plain_rep.tokens_per_forward:.2f};"
         f"decode_steps={plain_rep.decode_steps};"
         f"decoded={plain_rep.decoded_tokens}")
    for spec_k, rank in [(2, 0), (2, 60), (4, 60)]:
        sched, rep, us = run_one(2.0, 8, speculate=spec_k, draft_rank=rank)
        toks = {r.uid: list(r.generated) for r in sched.finished}
        buckets = ";".join(f"acc_prompt_{b}={v:.2f}"
                           for b, v in rep.acceptance_by_bucket.items())
        json_rows.append(_serving_row(
            f"spec_k{spec_k}_rank{rank or 'full'}", rep, us,
            speculate_k=spec_k, draft_rank=rank,
            tokens_match_plain=(toks == plain_toks)))
        emit(f"serving/spec_k{spec_k}_rank{rank or 'full'}", us,
             f"tok_per_forward={rep.tokens_per_forward:.2f};"
             f"acceptance={rep.acceptance_rate:.2f};"
             f"mean_accepted={rep.mean_accepted:.2f};{buckets};"
             f"verify_forwards={rep.decode_steps};"
             f"draft_forwards={rep.draft_forwards};"
             f"decoded={rep.decoded_tokens};"
             f"tokens_match_plain={toks == plain_toks}")

    # cross-request prefix caching on template traffic: 90% of requests share
    # a 64-token system prefix.  The cache-on run must emit the identical
    # token streams while serving the shared blocks from cache — hit rate
    # >= 0.8 and strictly lower mean TTFT than the cache-off row (both
    # asserted: the quantities are deterministic, arrivals are in steps).
    def run_shared(prefix_cache):
        scfg = serve_loop.SchedulerConfig(
            max_slots=4, block_size=8, num_blocks=96, max_new_tokens=16,
            max_len=96, prefill_bucket=8, prefill_chunk_tokens=8,
            prefix_cache=prefix_cache)
        sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
        t0 = time.time()
        rep = sched.run(shared_prefix_workload(vocab_size=cfg.vocab_size))
        us = (time.time() - t0) * 1e6 / max(rep.decode_steps, 1)
        return sched, rep, us

    off_sched, off_rep, off_us = run_shared(False)
    on_sched, on_rep, on_us = run_shared(True)
    off_toks = {r.uid: list(r.generated) for r in off_sched.finished}
    on_toks = {r.uid: list(r.generated) for r in on_sched.finished}
    match = on_toks == off_toks
    ttft_win = on_rep.ttft_steps_mean < off_rep.ttft_steps_mean
    assert match, "prefix cache changed token streams"
    assert on_rep.prefix_cache_hit_rate >= 0.8, on_rep.prefix_cache_hit_rate
    assert ttft_win, (on_rep.ttft_steps_mean, off_rep.ttft_steps_mean)
    json_rows.append(_serving_row(
        "shared_prefix_off", off_rep, off_us, prefix_cache=False,
        shared_prefix=64, ttft_steps_mean=round(off_rep.ttft_steps_mean, 2)))
    json_rows.append(_serving_row(
        "shared_prefix_on", on_rep, on_us, prefix_cache=True,
        shared_prefix=64, ttft_steps_mean=round(on_rep.ttft_steps_mean, 2),
        hit_rate=round(on_rep.prefix_cache_hit_rate, 4),
        hit_tokens=on_rep.prefix_cache_hit_tokens,
        cow_copies=on_rep.cow_copies,
        blocks_retained=on_rep.blocks_retained,
        tokens_match_off=match, ttft_lower_than_off=ttft_win))
    emit("serving/shared_prefix_off", off_us,
         f"ttft_steps={off_rep.ttft_steps_mean:.1f};"
         f"prefill_chunks={off_rep.prefill_chunks};"
         f"blocks_hw={off_rep.pool_high_water_blocks}")
    emit("serving/shared_prefix_on", on_us,
         f"hit_rate={on_rep.prefix_cache_hit_rate:.2f};"
         f"hit_tokens={on_rep.prefix_cache_hit_tokens};"
         f"cow={on_rep.cow_copies};"
         f"ttft_steps={on_rep.ttft_steps_mean:.1f};"
         f"prefill_chunks={on_rep.prefill_chunks};"
         f"blocks_hw={on_rep.pool_high_water_blocks};"
         f"tokens_match_off={match};ttft_lower_than_off={ttft_win}")

    # int8 pool capacity vs quality: the same fixed greedy workload through a
    # f32 pool (block_size 16) and an int8 pool (block_size 64 — roughly the
    # same bytes per block, so peak *blocks* compare capacity honestly), then
    # teacher-forced per-position top-1 agreement and ppl delta between the
    # two pools over the f32 streams.  Quantization is the first serving
    # feature that cannot be token-identical, so its wall is a pinned
    # agreement threshold instead (tests/test_quant.py pins the same property
    # suite-side); both inequalities below are asserted, not just recorded.
    from repro.core.cache import PagedKVPool
    qparams, qbuffers = lm.init(jax.random.PRNGKey(7), cfg)
    B, P, new = 4, 16, 48
    prompts = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)

    def run_pool(dtype, block_size, num_blocks):
        scfg = serve_loop.SchedulerConfig(
            max_slots=B, block_size=block_size, num_blocks=num_blocks,
            max_new_tokens=new, max_len=P + new + 1, cache_dtype=dtype)
        t0 = time.time()
        out, rep = serve_loop.generate_paged(qparams, qbuffers, cfg, prompts,
                                             new, scfg)
        us = (time.time() - t0) * 1e6 / max(rep.decode_steps, 1)
        return out, rep, us

    out_f, rep_f, us_f = run_pool(jnp.float32, 16, 24)
    _, rep_q, us_q = run_pool("int8", 64, 12)

    full = jnp.concatenate([prompts, jnp.asarray(out_f)], axis=1)
    n_tok = int(full.shape[1])

    def forced_logits(dtype, block_size):
        """Teacher-forced logits over the f32 streams: both pools score the
        IDENTICAL context, so agreement is per-position (no compounding of a
        single early argmax flip through every later token)."""
        pool = PagedKVPool(cfg, num_blocks=2 * B * (-(-n_tok // block_size)),
                           block_size=block_size, dtype=dtype)
        sms = []
        for b in range(B):
            pool.ensure_capacity(b, n_tok)
            sms.append(pool.prefill_slot_mapping(b, 0, n_tok, n_tok))
        logits, _ = lm.apply_prefill_paged(
            qparams, qbuffers, cfg, {"tokens": full}, pool.pages,
            jnp.asarray(np.stack(sms)))
        return np.asarray(logits, np.float32)[:, P - 1:n_tok - 1]

    l_f = forced_logits(jnp.float32, 16)
    l_q = forced_logits("int8", 64)
    top1_agreement = float((l_f.argmax(-1) == l_q.argmax(-1)).mean())
    targets = jnp.asarray(out_f)

    def forced_ppl(logits):
        logp = jax.nn.log_softmax(jnp.asarray(logits), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return float(np.exp(float(nll.mean())))

    ppl_f, ppl_q = forced_ppl(l_f), forced_ppl(l_q)
    blocks_ratio = rep_q.pool_high_water_blocks / rep_f.pool_high_water_blocks
    assert top1_agreement >= 0.98, top1_agreement
    assert blocks_ratio <= 0.55, blocks_ratio
    json_rows.append(_serving_row(
        "pool_capacity_f32", rep_f, us_f, block_size=16,
        bytes_per_block=16 * rep_f.pool_bytes_per_token,
        forced_ppl=round(ppl_f, 4)))
    json_rows.append(_serving_row(
        "pool_capacity_int8", rep_q, us_q, block_size=64,
        bytes_per_block=64 * rep_q.pool_bytes_per_token,
        peak_blocks_ratio_vs_f32=round(blocks_ratio, 4),
        top1_agreement_vs_f32=round(top1_agreement, 4),
        forced_ppl=round(ppl_q, 4),
        ppl_delta_vs_f32=round(ppl_q - ppl_f, 4)))
    emit("serving/pool_capacity_f32", us_f,
         f"blocks_hw={rep_f.pool_high_water_blocks};block_size=16;"
         f"bytes_tok={rep_f.pool_bytes_per_token};ppl={ppl_f:.3f}")
    emit("serving/pool_capacity_int8", us_q,
         f"blocks_hw={rep_q.pool_high_water_blocks};block_size=64;"
         f"bytes_tok={rep_q.pool_bytes_per_token};"
         f"peak_blocks_ratio={blocks_ratio:.3f};"
         f"top1_agreement={top1_agreement:.4f};"
         f"ppl_delta={ppl_q - ppl_f:+.4f}")

    # long-context sparse decode: block top-k over the paged pool at ~8x
    # the context of every other serving row.  A random-init model has
    # near-uniform attention and vanishing argmax margins, so it cannot
    # separate "selection missed a block that mattered" from "the logits
    # were a coin flip anyway"; a short bigram pretrain (~15s) gives the
    # proxy model confident margins, which makes teacher-forced top-1
    # agreement a real recall signal instead of noise.
    LONGCTX_TOPK, LONGCTX_RECENT = 4, 2
    LONGCTX_AGREEMENT_MIN = 0.95

    def _markov(rng, n):
        out = np.empty(n, np.int32)
        t = int(rng.integers(cfg.vocab_size))
        for i in range(n):
            out[i] = t
            t = (5 * t + 3) % cfg.vocab_size
        return out

    class _MarkovData:
        def __init__(self, batch, seq, seed=0):
            self.rng = np.random.default_rng(seed)
            self.batch, self.seq = batch, seq

        def __iter__(self):
            return self

        def __next__(self):
            toks = np.stack([_markov(self.rng, self.seq + 1)
                             for _ in range(self.batch)])
            return {"tokens": jnp.asarray(toks[:, :-1]),
                    "labels": jnp.asarray(toks[:, 1:]),
                    "loss_mask": jnp.ones((self.batch, self.seq),
                                          jnp.float32)}

    lparams, lbuffers = lm.init(jax.random.PRNGKey(0), cfg)
    lparams, _, _ = train_loop.train(
        lparams, lbuffers, cfg, train_loop.TrainConfig(lr=1e-3),
        iter(_MarkovData(8, 64)), 300)

    B, P, new, bs = 2, 512, 16, 16
    lrng = np.random.default_rng(11)
    lprompts = jnp.asarray(np.stack([_markov(lrng, P) for _ in range(B)]))

    def run_ctx(topk):
        # partial-width sparse decode requires swap eviction (recompute
        # prefill cannot reproduce sparse-generated streams; the pool is
        # ample here so neither path actually evicts)
        scfg = serve_loop.SchedulerConfig(
            max_slots=B, block_size=bs, num_blocks=96, max_new_tokens=new,
            max_len=P + new + 1, cache_dtype=jnp.float32,
            sparse_topk_blocks=topk, sparse_recent_blocks=LONGCTX_RECENT,
            eviction="swap" if topk else "recompute")
        t0 = time.time()
        out, rep = serve_loop.generate_paged(lparams, lbuffers, cfg,
                                             lprompts, new, scfg)
        us = (time.time() - t0) * 1e6 / max(rep.decode_steps, 1)
        return out, rep, us

    out_ld, rep_ld, us_ld = run_ctx(0)
    _, rep_ls, us_ls = run_ctx(LONGCTX_TOPK)

    # teacher-forced recall harness: prefill the dense greedy stream once,
    # then score every 4th position with one dense and one sparse decode
    # forward over the FROZEN pool (pages are immutable jnp trees; each
    # forward's scattered copy is discarded), so agreement is per-position
    # with no compounding of an early flip through later tokens.
    lfull = jnp.concatenate([lprompts, jnp.asarray(out_ld)], axis=1)
    ln_tok = int(lfull.shape[1])
    lmb = -(-ln_tok // bs)
    lpool = PagedKVPool(cfg, num_blocks=2 * B * lmb, block_size=bs,
                        block_summaries=True)
    lsms = []
    for b in range(B):
        lpool.ensure_capacity(b, ln_tok)
        lsms.append(lpool.prefill_slot_mapping(b, 0, ln_tok, ln_tok))
    _, lpool.pages = lm.apply_prefill_paged(
        lparams, lbuffers, cfg, {"tokens": lfull}, lpool.pages,
        jnp.asarray(np.stack(lsms)))
    lpages = lpool.pages
    lbt = jnp.asarray(lpool.block_table_array(list(range(B)), lmb))

    def _forced(topk):
        def f(tok, sm, ln):
            logits, _ = lm.apply_decode_paged(
                lparams, lbuffers, cfg, {"tokens": tok}, lpages, sm, lbt,
                ln, block_size=bs, sparse_topk=topk,
                sparse_recent=LONGCTX_RECENT)
            return logits[:, -1, :]
        return jax.jit(f)

    f_dense, f_sparse = _forced(0), _forced(LONGCTX_TOPK)
    lanes = list(range(B))
    agree = total = 0
    for pos in range(P // 2 - 1, ln_tok - 1, 4):
        tok = lfull[:, pos][:, None]
        sm = jnp.asarray(lpool.slot_mapping(lanes, [pos] * B))
        ln = jnp.full((B,), pos + 1, jnp.int32)
        a_d = np.asarray(jnp.argmax(f_dense(tok, sm, ln), -1))
        a_s = np.asarray(jnp.argmax(f_sparse(tok, sm, ln), -1))
        agree += int((a_d == a_s).sum())
        total += B
    longctx_agreement = agree / total

    # steady-state decode step at full context, post-compile: this is the
    # O(context) vs O(k*block) comparison the sparse path exists for,
    # without prefill/compile wall time diluting it.
    ltok = lfull[:, -1][:, None]
    lsm = jnp.asarray(lpool.slot_mapping(lanes, [ln_tok - 1] * B))
    lln = jnp.full((B,), ln_tok, jnp.int32)

    def _steady(f, reps=20):
        f(ltok, lsm, lln).block_until_ready()
        t0 = time.time()
        for _ in range(reps):
            f(ltok, lsm, lln).block_until_ready()
        return B * reps / (time.time() - t0)

    dense_tok_s = _steady(f_dense)
    sparse_tok_s = _steady(f_sparse)
    speedup = sparse_tok_s / dense_tok_s
    assert longctx_agreement >= LONGCTX_AGREEMENT_MIN, longctx_agreement
    assert sparse_tok_s > dense_tok_s, (sparse_tok_s, dense_tok_s)
    assert rep_ls.mean_selected_blocks < rep_ls.mean_candidate_blocks, \
        "longctx sparse run was not actually partial-width"
    json_rows.append(_serving_row(
        "longctx_dense", rep_ld, us_ld, context_tokens=ln_tok,
        decode_tok_s_steady=round(dense_tok_s, 1)))
    json_rows.append(_serving_row(
        f"longctx_sparse_k{LONGCTX_TOPK}", rep_ls, us_ls,
        context_tokens=ln_tok, sparse_topk=LONGCTX_TOPK,
        sparse_recent=LONGCTX_RECENT,
        mean_selected_blocks=round(rep_ls.mean_selected_blocks, 2),
        mean_candidate_blocks=round(rep_ls.mean_candidate_blocks, 2),
        decode_tok_s_steady=round(sparse_tok_s, 1),
        sparse_decode_speedup=round(speedup, 3),
        top1_agreement_vs_dense=round(longctx_agreement, 4)))
    emit("serving/longctx_dense", us_ld,
         f"context={ln_tok};decode_tok_s={dense_tok_s:.0f}")
    emit(f"serving/longctx_sparse_k{LONGCTX_TOPK}", us_ls,
         f"context={ln_tok};decode_tok_s={sparse_tok_s:.0f};"
         f"speedup={speedup:.2f};sel={rep_ls.mean_selected_blocks:.1f}/"
         f"{rep_ls.mean_candidate_blocks:.1f};"
         f"top1_agreement={longctx_agreement:.4f}")

    # multi-device scaling: tp head-shards absorbed attention inside a
    # replica, dp adds independent router replicas (runtime/router.py).
    # This process is pinned to ONE CPU device (conftest determinism), so
    # each device count runs repro.runtime.sharded_check in a subprocess
    # that forces its own host device count, all serving the identical
    # deterministic greedy workload through chunked prefill + swap
    # preemption.  Token identity vs single-device is ASSERTED — the
    # sharded path is bit-preserving, so a mismatch is a bug, not noise.
    import os as _os
    import subprocess as _sp
    repo = Path(__file__).resolve().parent.parent
    scaling = {}
    for devices, tp, dp in [(1, 1, 1), (2, 2, 1), (4, 2, 2), (8, 2, 4)]:
        env = dict(_os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count"
                             f"={devices}",
                   JAX_PLATFORMS="cpu")
        env["PYTHONPATH"] = str(repo / "src") + (
            _os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        proc = _sp.run([sys.executable, "-m", "repro.runtime.sharded_check",
                        "--devices", str(devices), "--tp", str(tp),
                        "--dp", str(dp), "--scenarios", "plain"],
                       capture_output=True, text=True, env=env, cwd=repo,
                       timeout=560)
        assert proc.returncode == 0, \
            f"sharded_check dev={devices} failed:\n{proc.stderr[-2000:]}"
        scaling[devices] = (tp, dp,
                            json.loads(proc.stdout)["scenarios"]["plain"])
    ref_tokens = scaling[1][2]["tokens"]
    for devices in sorted(scaling):
        tp, dp, sc = scaling[devices]
        rep, match = sc["report"], sc["tokens"] == ref_tokens
        assert match, f"device_count={devices} diverged from single-device"
        json_rows.append(dict(
            scenario=f"sharded_dev{devices}_tp{tp}_dp{dp}",
            device_count=devices, tp=tp, dp=dp,
            tok_s=round(rep["tok_s"], 2),
            ttft_ms_p50=round(rep["ttft_wall_p50_ms"], 2),
            ttft_ms_p95=round(rep["ttft_wall_p95_ms"], 2),
            completed=rep["completed"],
            preemptions=rep["preemptions"],
            routed=rep["routed"],
            imbalance=round(min(rep["imbalance"], 999.0), 3),
            occupancy_per_replica=[round(o, 4)
                                   for o in rep["occupancy_per_replica"]],
            pool_bytes_per_token_per_device=(
                rep["pool_bytes_per_token_per_device"]),
            tokens_match_single_device=match))
        emit(f"serving/sharded_dev{devices}_tp{tp}_dp{dp}", 0.0,
             f"tok_s={rep['tok_s']:.1f};"
             f"ttft_p50={rep['ttft_wall_p50_ms']:.0f};"
             f"ttft_p95={rep['ttft_wall_p95_ms']:.0f};"
             f"routed={rep['routed']};"
             f"bytes_tok_dev={rep['pool_bytes_per_token_per_device']};"
             f"tokens_match_single_device={match}")

    out = write_serving_json(json_rows)
    print(f"wrote {out} ({len(json_rows)} scenario rows, "
          f"schema v{SERVING_SCHEMA_VERSION})", file=sys.stderr)


ALL = {"table1": table1, "table2": table2, "fig5": fig5, "fig6": fig6,
       "kernels": kernels, "cache": cache_table, "serving": serving}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        ALL[name]()


if __name__ == "__main__":
    main()
