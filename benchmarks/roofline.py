"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--md out.md]
                                                 [--json out.json]

Per (arch × shape) cell, from experiments/dryrun/<mesh>/*.json:

    compute term    = HLO_FLOPs/device   / 197e12  (bf16 peak, TPU v5e)
    memory term     = HLO_bytes/device   / 819e9   (HBM bandwidth)
    collective term = coll_bytes/device  / 50e9    (per ICI link)

plus MODEL_FLOPS = 6·N_active·D, the useful-compute ratio, the dominant term,
and the roofline fraction  (MODEL_FLOPS / chips / peak) / max(term)  — the
fraction of bf16 peak each chip would sustain on *useful* flops if the
dominant term set the step time.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip, TPU v5e
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_accessed_per_device"]
    coll_dev = rec["collective_bytes_per_device"]
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    model_flops = 6.0 * rec["active_param_count"] * rec["tokens_per_step"]
    useful_ratio = model_flops / max(flops_dev * chips, 1.0)
    roofline_frac = (model_flops / chips / PEAK_FLOPS) / max(terms[dom], 1e-30)
    # decode cells: ideal memory = params(bf16) + compressed cache, read once
    ideal = None
    if rec["kind"] == "decode":
        cache_bytes = (rec["cache_floats_per_token"] * 2
                       * rec["tokens_per_step"] / max(rec["tokens_per_step"], 1))
        # cache over full context: floats/token × seq× batch × 2B
        ideal = (rec["param_count"] * 2 / chips) / HBM_BW
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dom, model_flops=model_flops, useful_ratio=useful_ratio,
        roofline_frac=roofline_frac,
        peak_gib=rec["memory"]["peak_estimate_bytes"] / 2**30,
        fits_16g=rec["memory"]["peak_estimate_bytes"] < 16 * 2**30,
    )


NOTES = {
    ("compute", "train"): "cut recompute (remat policy) / pad waste; MFU-bound",
    ("memory", "train"): "activation traffic — fuse/bigger per-chip batch",
    ("collective", "train"): "SP gathers + grad reduce dominate — overlap or shrink via bf16 grads / fewer repeats",
    ("compute", "prefill"): "S² attention flops — flash kernel target",
    ("memory", "prefill"): "KV write + activation traffic",
    ("collective", "prefill"): "SP gathers of 32k activations dominate",
    ("compute", "decode"): "GEMV-bound — batch more requests",
    ("memory", "decode"): "cache read/step — EliteKV ratio is the lever",
    ("collective", "decode"): "per-layer TP all-reduces of tiny tensors — batch or duplicate",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default="")
    ap.add_argument("--json", default="",
                    help="also write the analyzed rows as structured JSON "
                         "(same schema-versioned envelope as "
                         "BENCH_serving.json)")
    args = ap.parse_args()

    rows, skips = [], []
    for p in sorted(Path(args.dir, args.mesh).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("skipped"):
            skips.append((rec["arch"], rec["shape"], rec["reason"]))
            continue
        if "__" in p.stem and len(p.stem.split("__")) > 2:
            continue  # variants handled by §Perf
        rows.append(analyze(rec))

    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    hdr = (f"| arch | shape | compute s | memory s | collective s | dominant "
           f"| 6ND/HLO | roofline frac | peak GiB | fits 16G | next lever |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        note = NOTES.get((r["dominant"], r["kind"]), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3f} | "
            f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['peak_gib']:.1f} | {'✅' if r['fits_16g'] else '❌'} | {note} |")
    out = "\n".join(lines)
    print(out)
    if skips:
        print("\nskipped cells:")
        for a, s, why in skips:
            print(f"  {a} × {s}: {why}")
    worst = sorted((r for r in rows if r["kind"] == "train"),
                   key=lambda r: r["roofline_frac"])[:3]
    collbound = sorted(rows, key=lambda r: -r["t_collective"] /
                       max(r["t_compute"] + r["t_memory"], 1e-30))[:3]
    print("\nworst roofline fraction (train):",
          [(r["arch"], r["shape"], round(r["roofline_frac"], 3)) for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["shape"]) for r in collbound])
    if args.md:
        Path(args.md).write_text(out + "\n")
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"benchmark": "roofline", "schema_version": 1, "mesh": args.mesh,
             "rows": rows,
             "skipped": [{"arch": a, "shape": s, "reason": why}
                         for a, s, why in skips]}, indent=2) + "\n")
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
