"""Mamba-1 block (selective SSM) — falcon-mamba / jamba substrate.

TPU adaptation: the CUDA selective-scan kernel is replaced by a *chunked*
associative scan — ``lax.scan`` over sequence chunks with a parallel
``lax.associative_scan`` inside each chunk, bounding live memory to
``B × chunk × d_inner × d_state`` while keeping the scan depth ``S / chunk``.
Decode is the O(1) recurrent step over (conv_state, ssm_state) — no KV cache
exists, which is exactly why EliteKV is inapplicable here (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def _dt_rank(cfg) -> int:
    return cfg.dt_rank or -(-cfg.d_model // 16)


def init(key, cfg) -> Dict[str, Any]:
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias ~ softplus^-1(dt) with dt in [1e-3, 1e-1]
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    u = jax.random.uniform(ks[5], (di,), jnp.float32)
    dt_init = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))        # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (K, di), scale=K ** -0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * N)),
        "dt_w": dense_init(ks[3], (dtr, di), scale=dtr ** -0.5),
        "dt_b": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _conv_causal(xs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d.  xs [B,S,di], w [K,di]."""
    K = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    # unrolled taps (K is 4): avoids conv lowering quirks, stays MXU-free (VPU)
    out = jnp.zeros_like(xs)
    for t in range(K):
        out = out + pad[:, t:t + xs.shape[1], :] * w[t][None, None, :]
    return out + b.astype(xs.dtype)[None, None, :]


def _ssm_params(params, cfg, xs):
    """Per-token Δ, B, C from the conv output.  xs [B,S,di] (post-silu)."""
    dt_ = xs.dtype
    dtr = _dt_rank(cfg)
    N = cfg.ssm_state
    proj = xs @ params["x_proj"].astype(dt_)                  # [B,S,dtr+2N]
    dt_low, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        dt_low @ params["dt_w"].astype(dt_) + params["dt_b"].astype(dt_))
    A = -jnp.exp(params["A_log"])                             # [di,N] fp32
    return dt, Bm, Cm, A


def _chunk_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def ssm_scan(dt, xs, Bm, Cm, A, D, h0=None, chunk: int = 128,
             unroll: bool = False):
    """Selective scan.  Shapes: dt,xs [B,S,di]; Bm,Cm [B,S,N]; A [di,N].

    Returns y [B,S,di] and final state h [B,di,N] (fp32).
    """
    B, S, di = xs.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    n_pad = (-S) % chunk
    if n_pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, n_pad)) + ((0, 0),) * (t.ndim - 2))
        dt, xs, Bm, Cm = z(dt), z(xs), z(Bm), z(Cm)
    Sp = S + n_pad
    nc = Sp // chunk
    resh = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    dt_c, xs_c, Bm_c, Cm_c = resh(dt), resh(xs), resh(Bm), resh(Cm)

    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)

    def step(h_in, inp):
        dtk, xk, Bk, Ck = inp                                  # [B,chunk,...]
        dtk32 = dtk.astype(jnp.float32)
        dA = jnp.exp(dtk32[..., None] * A[None, None])         # [B,ck,di,N]
        dBx = (dtk32 * xk.astype(jnp.float32))[..., None] * Bk.astype(jnp.float32)[:, :, None, :]
        aprod, bacc = jax.lax.associative_scan(_chunk_combine, (dA, dBx), axis=1)
        h_ts = aprod * h_in[:, None] + bacc                    # [B,ck,di,N]
        y = jnp.einsum("bsdn,bsn->bsd", h_ts, Ck.astype(jnp.float32))
        y = y + D[None, None] * xk.astype(jnp.float32)
        return h_ts[:, -1], y.astype(xs.dtype)

    if unroll:  # accurate HLO flop accounting for the dry-run
        h, outs = h0, []
        for i in range(nc):
            h, y = step(h, (dt_c[i], xs_c[i], Bm_c[i], Cm_c[i]))
            outs.append(y)
        return jnp.concatenate(outs, axis=1)[:, :S], h
    # remat each chunk: without it the backward saves the [B,chunk,di,N]
    # state-expanded intermediates of EVERY chunk (~ S*di*N*4 bytes -- 100s of
    # GiB at 4k x 8192 x 16); with it only the [B,di,N] carry chain persists.
    h_fin, ys = jax.lax.scan(jax.checkpoint(step), h0, (dt_c, xs_c, Bm_c, Cm_c))
    y = ys.swapaxes(0, 1).reshape(B, Sp, di)[:, :S]
    return y, h_fin


def apply_full(params, cfg, x, return_state: bool = False, constrain=lambda n, t: t):
    """x [B,S,d] → y [B,S,d]  (optionally + (conv_state, ssm_state) for prefill)."""
    dt_ = x.dtype
    di = cfg.d_inner
    xz = x @ params["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, z = constrain("ssm_h", xs), constrain("ssm_h", z)
    xs_conv = _conv_causal(xs, params["conv_w"].astype(dt_), params["conv_b"])
    xs_act = jax.nn.silu(xs_conv)
    dt, Bm, Cm, A = _ssm_params(params, cfg, xs_act)
    y, h_fin = ssm_scan(dt, xs_act, Bm, Cm, A, params["D"],
                        chunk=cfg.ssm_chunk, unroll=cfg.ssm_unroll)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    if return_state:
        K = cfg.ssm_conv
        conv_state = xs[:, -(K - 1):, :] if K > 1 else jnp.zeros((x.shape[0], 0, di), dt_)
        return out, (conv_state, h_fin)
    return out


def init_state(cfg, batch: int, dtype=jnp.bfloat16):
    K, di, N = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, K - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, N), jnp.float32),
    }


def apply_decode(params, cfg, x, state, constrain=lambda n, t: t) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token recurrent step.  x [B,1,d]."""
    dt_ = x.dtype
    K = cfg.ssm_conv
    xz = x @ params["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)                         # [B,1,di]
    xs, z = constrain("ssm_h", xs), constrain("ssm_h", z)
    window = jnp.concatenate([state["conv"].astype(dt_), xs], axis=1)  # [B,K,di]
    w = params["conv_w"].astype(dt_)
    xc = jnp.einsum("bkd,kd->bd", window, w) + params["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)[:, None, :]                          # [B,1,di]
    dt, Bm, Cm, A = _ssm_params(params, cfg, xc)
    dt32 = dt[:, 0].astype(jnp.float32)                       # [B,di]
    dA = jnp.exp(dt32[..., None] * A[None])                   # [B,di,N]
    dBx = (dt32 * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"][None] * xc[:, 0].astype(jnp.float32)
    y = (y.astype(dt_) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = y @ params["out_proj"].astype(dt_)
    new_state = {"conv": window[:, 1:, :].astype(state["conv"].dtype), "ssm": h}
    return out, new_state
