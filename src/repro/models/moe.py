"""Mixture-of-Experts FFN: top-k router + three dispatch implementations.

  * ``dense``  — oracle: every expert computes every token (exact, O(E) flops);
                 correctness reference for tests.
  * ``ragged`` — sort-by-expert + ``jax.lax.ragged_dot`` grouped GEMM
                 (MegaBlocks idea, TPU-native; single-shard hot path).
  * ``ep``     — expert-parallel via ``shard_map`` over the "model" mesh axis:
                 activations replicated over model (as in the TP block), each
                 shard computes its local experts with GShard-style static
                 capacity, one psum over "model" combines (same collective cost
                 as a TP FFN all-reduce — no all-to-all needed).

Router: softmax → top-k → renormalize over the k gates (Qwen/Mixtral style),
with the standard Switch load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


def init(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    E = cfg.n_experts
    f = cfg.moe_dff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, f)),
        "w_up": dense_init(ks[2], (E, d, f)),
        "w_down": dense_init(ks[3], (E, f, d), in_axis=1),
    }
    if cfg.dense_residual:
        from repro.models.layers import mlp_init
        p["dense"] = mlp_init(ks[4], d, cfg.d_ff)
    return p


def _route(params, cfg, xf):
    """xf [T,d] → (gates [T,k], idx [T,k] int32, aux scalar)."""
    # bf16 GEMM with fp32 accumulation — avoids materializing an fp32 copy of
    # the [T, d] activations just for the router
    logits = jnp.einsum("td,de->te", xf, params["router"].astype(xf.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [T,E]
    top_p, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * P_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                             # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1)), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates.astype(xf.dtype), idx.astype(jnp.int32), aux


def _swiglu_batched(x_disp, wg, wu, wd):
    """x_disp [E,C,d] × per-expert weights → [E,C,d]."""
    dt = x_disp.dtype
    g = jnp.einsum("ecd,edf->ecf", x_disp, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x_disp, wu.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dt))


# ---------------------------------------------------------------------------
def apply_dense(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: all experts on all tokens, combined by gates."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gates, idx, aux = _route(params, cfg, xf)
    dt = x.dtype
    g = jnp.einsum("td,edf->etf", xf, params["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->etf", xf, params["w_up"].astype(dt))
    y_all = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, params["w_down"].astype(dt))
    comb = jnp.zeros((xf.shape[0], cfg.n_experts), dt)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], idx].set(gates)
    y = jnp.einsum("te,etd->td", comb, y_all)
    y = _maybe_dense_residual(params, cfg, xf, y)
    return y.reshape(B, S, d), aux


def apply_ragged(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort + ragged_dot grouped GEMM (single shard)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    gates, idx, aux = _route(params, cfg, xf)
    flat = idx.reshape(-1)                                    # [T*k]
    order = jnp.argsort(flat, stable=True)
    token_of = order // k
    xs = xf[token_of]                                         # [T*k, d]
    group_sizes = jnp.bincount(flat, length=E).astype(jnp.int32)
    dt = x.dtype
    g = jax.lax.ragged_dot(xs, params["w_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, params["w_up"].astype(dt), group_sizes)
    y = jax.lax.ragged_dot(jax.nn.silu(g) * u, params["w_down"].astype(dt), group_sizes)
    inv = jnp.argsort(order)                                  # unsort
    y = y[inv] * gates.reshape(-1, 1)
    y = y.reshape(T, k, d).sum(axis=1)
    y = _maybe_dense_residual(params, cfg, xf, y)
    return y.reshape(B, S, d), aux


def _ep_local(params, cfg, x, E_loc: int, capacity: int, axis: str,
              fsdp_axes=(), data_axes=("data",)):
    """Body run per (data, model) shard inside shard_map.

    Expert weights arrive EP-sharded on E (model axis) and ZeRO-3-sharded on
    the hidden dim over the data axes; they are all-gathered here layer-by-
    layer (the FSDP collective, visible in the roofline), used, and dropped.
    """
    B, S, d = x.shape
    dt = x.dtype
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if fsdp_axes:
        wg = jax.lax.all_gather(wg.astype(dt), fsdp_axes, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu.astype(dt), fsdp_axes, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd.astype(dt), fsdp_axes, axis=1, tiled=True)
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    k = cfg.top_k
    gates, idx, aux = _route(params, cfg, xf)                 # router replicated
    aux = jax.lax.pmean(aux, tuple(data_axes) + (axis,) if data_axes else axis)
    e0 = jax.lax.axis_index(axis) * E_loc
    flat = idx.reshape(-1)                                    # [T*k]
    gflat = gates.reshape(-1)
    local = (flat >= e0) & (flat < e0 + E_loc)
    lidx = jnp.where(local, flat - e0, E_loc)                 # E_loc = drop bucket
    # ---- sort-based dispatch (MegaBlocks-style): small index tables + row
    # gathers.  (A scatter of [T·k, d] row updates lowers to elementwise
    # scatters with [T·k, d] u32 index tensors — gigabytes of pure index.)
    order = jnp.argsort(lidx, stable=True)                    # assignments by expert
    sorted_lidx = lidx[order]
    counts = jnp.bincount(lidx, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - starts[sorted_lidx]            # slot within expert
    token_of_sorted = (order // k).astype(jnp.int32)
    valid = (sorted_lidx < E_loc) & (rank < capacity)
    # slot→token table [E_loc, C]; sentinel T = zero row of xf_pad
    slot_token = jnp.full((E_loc, capacity), T, jnp.int32)
    slot_token = slot_token.at[sorted_lidx, rank.astype(jnp.int32)].set(
        jnp.where(valid, token_of_sorted, T), mode="drop")
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_disp = xf_pad[slot_token]                               # [E_loc, C, d]
    y_disp = _swiglu_batched(x_disp, wg, wu, wd)
    # combine: per-assignment row gather (dropped → 0), weight, sum over k
    slot_of = jnp.zeros((T * k,), jnp.int32).at[order].set(rank.astype(jnp.int32))
    kept = (lidx < E_loc) & (slot_of < capacity)
    y_tok = y_disp[jnp.minimum(lidx, E_loc - 1),
                   jnp.minimum(slot_of, capacity - 1)]        # [T*k, d]
    y_tok = jnp.where(kept[:, None], y_tok, 0.0) * gflat[:, None]
    y = y_tok.reshape(T, k, d).sum(axis=1)
    if cfg.dense_residual and "dense" in params:
        # arctic parallel dense MLP: f-sharded over the model axis, its partial
        # sums ride the same psum as the expert combine
        dn = params["dense"]
        hdn = jax.nn.silu(xf @ dn["w_gate"].astype(dt)) * (xf @ dn["w_up"].astype(dt))
        y = y + hdn @ dn["w_down"].astype(dt)
    y = jax.lax.psum(y, axis)
    return y.reshape(B, S, d), aux


def apply_ep(params, cfg, x, mesh, data_axes=("data",), model_axis="model",
             capacity_factor: float = 1.25, fsdp: bool = True,
             remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel dispatch via shard_map.  x replicated over model axis;
    expert weights P(model, ·, data…) = EP × ZeRO-3."""
    E = cfg.n_experts
    ep = mesh.shape[model_axis]
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    bshard = x.shape[0] % n_data == 0
    if not bshard:
        n_data = 1              # batch-1 decode: tokens replicated over data
    T_loc = (x.shape[0] // n_data) * x.shape[1]
    capacity = max(8, int(math.ceil(T_loc * cfg.top_k / E * capacity_factor)))
    dspec = (tuple(data_axes) if len(data_axes) > 1 else data_axes[0]) if bshard else None
    f = cfg.moe_dff or cfg.d_ff
    fsdp_axes = tuple(data_axes) if (fsdp and f % math.prod(
        mesh.shape[a] for a in data_axes) == 0) else ()
    fspec = (tuple(data_axes) if len(data_axes) > 1 else data_axes[0]) if fsdp_axes else None

    wspec = {
        "router": P(),
        "w_gate": P(model_axis, None, fspec),
        "w_up": P(model_axis, None, fspec),
        "w_down": P(model_axis, fspec, None),
    }
    if "dense" in params:
        wspec["dense"] = {"w_gate": P(None, model_axis),
                          "w_up": P(None, model_axis),
                          "w_down": P(model_axis, None)}

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        lambda p, xx: _ep_local(p, cfg, xx, E_loc, capacity, model_axis,
                                fsdp_axes=fsdp_axes,
                                data_axes=tuple(data_axes) if bshard else ()),
        mesh=mesh,
        in_specs=(wspec, P(dspec, None, None)),
        out_specs=(P(dspec, None, None), P()),
        check_rep=False,
    )
    if remat:
        # §Perf iteration: jax.checkpoint does NOT see through shard_map from
        # an enclosing scope, so without this every MoE internal ([E_loc,C,f]
        # hiddens, dispatch gathers) is saved per layer for the backward —
        # tens of GiB at 94 layers.  Remat here keeps only (x, weights).
        fn = jax.checkpoint(fn)
    return fn(params, x)


def _maybe_dense_residual(params, cfg, xf, y):
    if cfg.dense_residual and "dense" in params:
        from repro.models.layers import mlp
        y = y + mlp(params["dense"], xf)
    return y


def apply(params, cfg, x, impl: str = "ragged", mesh=None,
          data_axes=("data",), model_axis="model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "dense":
        return apply_dense(params, cfg, x)
    if impl == "ragged":
        return apply_ragged(params, cfg, x)
    if impl == "ep":
        return apply_ep(params, cfg, x, mesh, data_axes, model_axis)
    raise ValueError(impl)
