"""Baseline GQA/MHA attention with full RoPE (the paper's starting point).

Three entry modes:
  * ``full``   — training / whole-sequence forward (causal).
  * ``prefill``— same math, but also writes the KV cache.
  * ``decode`` — one token per call against the cache.

Sharding-friendliness notes (GSPMD):
  * GQA is computed by *repeating* K/V to the query-head count — an explicit
    gather GSPMD shards cleanly on the head axis (reshape-to-groups einsums
    make GSPMD reshard when TP > n_kv, which covers most assigned archs).
  * Long sequences use *q-chunked* attention (``lax.scan`` over query blocks,
    exact row softmax) so the [S,S] score matrix never materializes — the
    XLA-level analogue of the Pallas flash kernel in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rope as rope_lib

NEG_INF = -1e30


def init(key, cfg) -> Dict[str, Any]:
    d, dh, nh, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    from repro.models.layers import dense_init
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, nh, dh)),
        "wk": dense_init(kk, (d, nkv, dh)),
        "wv": dense_init(kv, (d, nkv, dh)),
        "wo": dense_init(ko, (nh, dh, d), in_axis=2, scale=(nh * dh) ** -0.5),
    }


def _auto_chunk(Sq: int, chunk_q) -> Optional[int]:
    if chunk_q is not None:
        return chunk_q if Sq > chunk_q and Sq % chunk_q == 0 else None
    if Sq >= 4096 and Sq % 1024 == 0:
        return 1024
    return None


_NOOP = lambda name, x: x


def _attend(q, k, v, q_group: int, scale: float, *, q_offset=0,
            chunk_q: Optional[int] = None, constrain=_NOOP,
            unroll: bool = False) -> jnp.ndarray:
    """Causal attention.  q [B,Sq,nh,dh]; k,v [B,Sk,nkv,dh]; mask:
    key j visible to query i iff  j <= i + q_offset  (decode: Sq=1,
    q_offset=index).  Returns [B,Sq,nh,dh]."""
    B, Sq, nh, dh = q.shape
    Sk = k.shape[1]
    if q_group > 1:
        k = constrain("heads4", jnp.repeat(k, q_group, axis=2))
        v = constrain("heads4", jnp.repeat(v, q_group, axis=2))
    kpos = jnp.arange(Sk)[None, :]

    def block(qc, start):
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, k,
                       preferred_element_type=jnp.float32) * scale
        qpos = (start + jnp.arange(qc.shape[1]))[:, None]
        s = jnp.where(kpos <= qpos + q_offset, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    cq = _auto_chunk(Sq, chunk_q)
    if cq is None:
        return block(q, 0)
    n = Sq // cq
    if unroll:  # accurate HLO flop accounting for the dry-run (no while loop)
        outs = [block(q[:, i * cq:(i + 1) * cq], i * cq) for i in range(n)]
        return jnp.concatenate(outs, axis=1)
    qs = jnp.moveaxis(q.reshape(B, n, cq, nh, dh), 1, 0)      # [n,B,cq,nh,dh]

    def step(_, xs):
        qc, i = xs
        return None, block(qc, i * cq)

    # remat each chunk: without it the backward saves the stacked per-chunk
    # probabilities ([n, B, h, cq, S] — tens of GiB at 4k/32k); with it only
    # the chunk outputs persist and scores are recomputed in the backward
    # (exactly the flash-attention backward trade).
    _, os = jax.lax.scan(jax.checkpoint(step), None, (qs, jnp.arange(n)))
    return jnp.moveaxis(os, 0, 1).reshape(B, Sq, nh, dh)


def causal_mask(Sq: int, Sk: int, offset: int = 0, dtype=jnp.float32):
    """Additive causal mask (kept for reference paths/tests)."""
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    return jnp.where(kj <= qi + offset, 0.0, NEG_INF).astype(dtype)


def _qkv(params, cfg, x, positions, constrain=_NOOP):
    dt = x.dtype
    q = constrain("attn_q", jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt)))
    k = constrain("attn_kv", jnp.einsum("bsd,dhe->bshe", x, params["wk"].astype(dt)))
    v = constrain("attn_kv", jnp.einsum("bsd,dhe->bshe", x, params["wv"].astype(dt)))
    q = constrain("attn_q", rope_lib.apply_rope(q, positions, cfg.rope_theta))
    k = constrain("attn_kv", rope_lib.apply_rope(k, positions, cfg.rope_theta))
    return q, k, v


def apply_full(params, cfg, x, positions, constrain=_NOOP) -> jnp.ndarray:
    q, k, v = _qkv(params, cfg, x, positions, constrain)
    o = _attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5,
                chunk_q=cfg.attn_chunk_q, constrain=constrain,
                unroll=cfg.attn_chunk_unroll)
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x.dtype))


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    nkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, nkv, dh), dtype),
        "v": jnp.zeros((batch, max_len, nkv, dh), dtype),
    }


def apply_prefill(params, cfg, x, positions, cache, constrain=_NOOP) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    q, k, v = _qkv(params, cfg, x, positions, constrain)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    o = _attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5,
                chunk_q=cfg.attn_chunk_q, constrain=constrain,
                unroll=cfg.attn_chunk_unroll)
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x.dtype)), new_cache


def apply_decode(params, cfg, x, index, cache, constrain=_NOOP) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """x: [B, 1, d]; index: scalar position of the new token."""
    dt = x.dtype
    pos = jnp.full((x.shape[0], 1), index, jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos, constrain)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, index, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, index, 0, 0))
    o = _attend(q, ck.astype(dt), cv.astype(dt), cfg.q_group,
                cfg.head_dim ** -0.5, q_offset=index, constrain=constrain)
    out = jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(dt))
    return out, {"k": ck, "v": cv}
