"""Unified decoder-only LM covering every assigned architecture.

Layer stacking: layers are grouped into *superblocks* of ``P = block_period``
positions (P=8 for jamba's 1:7 mamba:attn interleave + MoE-every-2; P=1 for
homogeneous stacks).  The ``num_layers / P`` superblocks are parameter-stacked
and driven by ``lax.scan`` (+ optional ``jax.checkpoint``), keeping HLO size
O(1) in depth — essential at 94-layer/128-expert dry-run scale.

Modes:
  * ``apply_train``   — logits over the full sequence.
  * ``apply_prefill`` — logits + filled cache.
  * ``apply_decode``  — one token + cache → logits + new cache.
  * ``capture_attn_inputs`` — per-attention-layer normed inputs (RoPElite search).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import elite_attention
from repro.models import attention as gqa_attention
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import (cross_entropy, dense_init, embed, embed_init,
                                 mlp, mlp_init, rmsnorm, rmsnorm_init, unembed)

_NOOP = lambda name, x: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, layer_idx: int):
    """(params, buffers) for one absolute layer index."""
    kinds = (cfg.layer_kind(layer_idx), cfg.ffn_kind(layer_idx))
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"attn_norm": rmsnorm_init(cfg.d_model)}
    b: Dict[str, Any] = {}
    if kinds[0] == "attn":
        if cfg.elitekv.enabled:
            p["attn"], b_attn = elite_attention.init(ks[0], cfg)
            b.update(b_attn)
        else:
            p["attn"] = gqa_attention.init(ks[0], cfg)
    else:
        p["attn"] = mamba_lib.init(ks[0], cfg)
    if kinds[1] != "none":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model)
        if kinds[1] == "moe":
            p["ffn"] = moe_lib.init(ks[1], cfg)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p, b


def init(key, cfg) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    P_ = cfg.block_period
    assert cfg.num_layers % P_ == 0 or P_ == 1, (cfg.num_layers, P_)
    n_super = cfg.num_layers // P_ if cfg.num_layers % P_ == 0 else cfg.num_layers
    keys = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    buffers: Dict[str, Any] = {"blocks": {}}
    Vp = cfg.padded_vocab
    if cfg.frontend != "audio":
        params["embed"] = embed_init(keys[0], Vp, cfg.d_model)
    if cfg.frontend == "audio" or not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(keys[1], (cfg.d_model, Vp), scale=0.02)}
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    blocks: Dict[str, Any] = {}
    bkeys = jax.random.split(keys[2], cfg.num_layers)
    for p_pos in range(P_):
        layer_keys = [bkeys[s * P_ + p_pos] for s in range(n_super)]
        inits = [_init_layer(k, cfg, s * P_ + p_pos) for s, k in enumerate(layer_keys)]
        stacked_p = jax.tree.map(lambda *xs: jnp.stack(xs), *[i[0] for i in inits])
        stacked_b = jax.tree.map(lambda *xs: jnp.stack(xs), *[i[1] for i in inits])
        blocks[f"p{p_pos}"] = stacked_p
        buffers["blocks"][f"p{p_pos}"] = stacked_b
    params["blocks"] = blocks
    return params, buffers


# ---------------------------------------------------------------------------
# embedding / frontend stubs
# ---------------------------------------------------------------------------

def _embed_step(params, cfg, batch: Dict[str, Any]):
    """Token/frame embedding dispatch shared by every prefill/decode entry
    point: audio frontends feed raw frames, everything else embeds tokens."""
    if cfg.frontend == "audio":
        return batch["frames"].astype(cfg.dtype)
    return embed(params["embed"], batch["tokens"], cfg.dtype)


def _embed_inputs(params, cfg, batch: Dict[str, Any], dtype):
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        txt = embed(params["embed"], batch["tokens"], dtype)
        return jnp.concatenate([batch["patch_embeds"].astype(dtype), txt], axis=1)
    return _embed_step(params, cfg, batch)


def _logits(params, cfg, h, constrain=_NOOP):
    if cfg.tie_embeddings and cfg.frontend != "audio":
        out = unembed(params["embed"], h)
    else:
        out = h.astype(jnp.float32) @ params["lm_head"]["w"].astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask Megatron-style vocab padding
        out = jnp.where(jnp.arange(out.shape[-1]) < cfg.vocab_size, out, -1e30)
    return constrain("logits", out)


# ---------------------------------------------------------------------------
# superblock body
# ---------------------------------------------------------------------------

def _run_layer(p, b, cfg, p_pos: int, h, positions, mode, cache, index,
               moe_impl, mesh, constrain, data_axes=("data",), paged=None):
    kind = cfg.layer_kind(p_pos)
    ffn_kind = cfg.ffn_kind(p_pos)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    hn = rmsnorm(p["attn_norm"], h, cfg.norm_eps)
    # double pin: norm output stays S-sharded (bf16), then the SP all-gather
    # happens exactly here — on the bf16 tensor, not an f32 norm intermediate
    hn = constrain("attn_in", constrain("attn_in_sharded", hn))
    if kind == "attn":
        if cfg.elitekv.enabled:
            if mode == "train":
                a = elite_attention.apply_full(p["attn"], cfg, b, hn, positions,
                                               constrain=constrain)
            elif mode == "prefill" and paged is not None:
                a, new_cache = elite_attention.apply_prefill_paged(
                    p["attn"], cfg, b, hn, positions, cache,
                    paged["slot_mapping"],
                    block_tables=paged.get("block_tables"),
                    prefix_lens=paged.get("prefix_lens"),
                    block_size=paged.get("block_size", 0),
                    constrain=constrain, mesh=paged.get("mesh"))
            elif mode == "prefill":
                a, new_cache = elite_attention.apply_prefill(
                    p["attn"], cfg, b, hn, positions, cache, constrain=constrain)
            elif paged is not None and paged.get("verify"):
                a, new_cache = elite_attention.apply_verify_paged(
                    p["attn"], cfg, b, hn, cache, paged["slot_mapping"],
                    paged["block_tables"], paged["q_offsets"],
                    paged["lengths"], paged["block_size"],
                    use_kernel=paged.get("use_kernel", True),
                    constrain=constrain, mesh=paged.get("mesh"))
            elif paged is not None:
                a, new_cache = elite_attention.apply_decode_paged(
                    p["attn"], cfg, b, hn, cache, paged["slot_mapping"],
                    paged["block_tables"], paged["lengths"],
                    paged["block_size"], use_kernel=paged.get("use_kernel", True),
                    constrain=constrain, mesh=paged.get("mesh"),
                    sparse_topk=paged.get("sparse_topk", 0),
                    sparse_recent=paged.get("sparse_recent", 0))
            else:
                a, new_cache = elite_attention.apply_decode(
                    p["attn"], cfg, b, hn, index, cache, constrain=constrain)
        else:
            if mode == "train":
                a = gqa_attention.apply_full(p["attn"], cfg, hn, positions,
                                             constrain=constrain)
            elif mode == "prefill":
                a, new_cache = gqa_attention.apply_prefill(
                    p["attn"], cfg, hn, positions, cache, constrain=constrain)
            else:
                a, new_cache = gqa_attention.apply_decode(
                    p["attn"], cfg, hn, index, cache, constrain=constrain)
    else:  # mamba
        if mode == "train":
            a = mamba_lib.apply_full(p["attn"], cfg, hn, constrain=constrain)
        elif mode == "prefill":
            a, (conv_s, ssm_s) = mamba_lib.apply_full(p["attn"], cfg, hn, return_state=True,
                                                      constrain=constrain)
            new_cache = {"conv": conv_s.astype(cache["conv"].dtype), "ssm": ssm_s}
        else:
            a, new_cache = mamba_lib.apply_decode(p["attn"], cfg, hn, cache,
                                                  constrain=constrain)
    h = constrain("residual", h + constrain("attn_out", a))
    if ffn_kind != "none":
        hn = constrain("attn_in", constrain(
            "attn_in_sharded", rmsnorm(p["ffn_norm"], h, cfg.norm_eps)))
        if ffn_kind == "moe":
            f, aux = moe_lib.apply(p["ffn"], cfg, hn, impl=moe_impl, mesh=mesh,
                                   data_axes=data_axes)
        else:
            f = mlp(p["ffn"], hn, constrain=constrain)
        h = constrain("residual", h + constrain("ffn_out", f))
    return h, aux, new_cache


def _superblock(cfg, mode, moe_impl, mesh, constrain, positions, index,
                data_axes=("data",), paged=None):
    """Returns a scan body: (carry=(h, aux), xs=(params, buffers, cache)) → ..."""

    def body(carry, xs):
        h, aux_acc = carry
        p_blk, b_blk, c_blk, capture = xs
        caps = {}
        for p_pos in range(cfg.block_period):
            key = f"p{p_pos}"
            cache_p = c_blk.get(key) if c_blk else None
            if capture is not None and cfg.layer_kind(p_pos) == "attn":
                caps[key] = rmsnorm(p_blk[key]["attn_norm"], h, cfg.norm_eps)
            h, aux, nc = _run_layer(
                p_blk[key], b_blk.get(key, {}), cfg, p_pos, h, positions, mode,
                cache_p, index, moe_impl, mesh, constrain, data_axes,
                paged=paged)
            aux_acc = aux_acc + aux
            if c_blk:
                c_blk = dict(c_blk)
                c_blk[key] = nc
        ys = c_blk if mode in ("prefill", "decode") else (caps if capture is not None else None)
        return (h, aux_acc), ys

    return body


def _scan_blocks(params, buffers, cfg, h, positions, mode="train", cache=None,
                 index=None, moe_impl="ragged", mesh=None, constrain=_NOOP,
                 capture: bool = False, data_axes=("data",), paged=None):
    P_ = cfg.block_period
    n_super = cfg.num_layers // P_
    body = _superblock(cfg, mode, moe_impl, mesh, constrain, positions, index,
                       data_axes=data_axes, paged=paged)
    if cfg.remat:
        policy = {
            "dots": jax.checkpoint_policies.dots_saveable,
            "none": None,
            "full": jax.checkpoint_policies.nothing_saveable,
        }[cfg.remat_policy if cfg.remat_policy != "none" else "none"]
        body = jax.checkpoint(body, policy=policy) if policy is not None else jax.checkpoint(body)
    cache_blocks = cache["blocks"] if cache is not None else {}
    cap_xs = jnp.zeros((n_super,), jnp.int32) if capture else None
    xs = (params["blocks"], buffers["blocks"], cache_blocks, cap_xs)
    if not cfg.scan_layers:  # unrolled (dry-run flop accounting / tiny models)
        carry = (h, jnp.zeros((), jnp.float32))
        ys_list = []
        for s_i in range(n_super):
            xs_s = jax.tree.map(lambda t: t[s_i], xs)
            carry, ys_s = body(carry, xs_s)
            ys_list.append(ys_s)
        h, aux = carry
        ys = (None if ys_list[0] is None
              else jax.tree.map(lambda *a: jnp.stack(a), *ys_list))
        return h, aux, ys
    (h, aux), ys = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs,
                                unroll=cfg.scan_unroll)
    return h, aux, ys


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def apply_train(params, buffers, cfg, batch, moe_impl="ragged", mesh=None,
                constrain=_NOOP, data_axes=("data",), return_hidden=False):
    """→ (logits [B,S,V] fp32, aux_loss scalar) — or (h, aux) if return_hidden."""
    h = _embed_inputs(params, cfg, batch, cfg.dtype)
    h = constrain("embed", h)
    S = h.shape[1]
    positions = jnp.arange(S)
    h, aux, _ = _scan_blocks(params, buffers, cfg, h, positions, mode="train",
                             moe_impl=moe_impl, mesh=mesh, constrain=constrain,
                             data_axes=data_axes)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, aux
    return _logits(params, cfg, h, constrain), aux


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache pytree: {"index": scalar, "blocks": {p*: stacked layer caches}}."""
    P_ = cfg.block_period
    n_super = cfg.num_layers // P_
    blocks = {}
    for p_pos in range(P_):
        if cfg.layer_kind(p_pos) == "attn":
            one = (elite_attention.init_cache(cfg, batch, max_len, dtype)
                   if cfg.elitekv.enabled else
                   gqa_attention.init_cache(cfg, batch, max_len, dtype))
        else:
            one = mamba_lib.init_state(cfg, batch, dtype)
        blocks[f"p{p_pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), one)
    return {"index": jnp.zeros((), jnp.int32), "blocks": blocks}


def apply_prefill(params, buffers, cfg, batch, cache, moe_impl="ragged",
                  mesh=None, constrain=_NOOP, data_axes=("data",)):
    """Full forward that also fills the cache.  → (logits, new_cache)."""
    h = _embed_inputs(params, cfg, batch, cfg.dtype)
    h = constrain("embed", h)
    S = h.shape[1]
    positions = jnp.arange(S)
    h, aux, new_blocks = _scan_blocks(
        params, buffers, cfg, h, positions, mode="prefill", cache=cache,
        moe_impl=moe_impl, mesh=mesh, constrain=constrain, data_axes=data_axes)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h, constrain)
    return logits, {"index": jnp.asarray(S, jnp.int32), "blocks": new_blocks}


def apply_decode(params, buffers, cfg, batch, cache, moe_impl="ragged",
                 mesh=None, constrain=_NOOP, data_axes=("data",)):
    """One new token.  batch["tokens"]: [B,1].  → (logits [B,1,V], new_cache)."""
    h = _embed_step(params, cfg, batch)
    index = cache["index"]
    positions = jnp.full((h.shape[0], 1), index, jnp.int32)
    h, aux, new_blocks = _scan_blocks(
        params, buffers, cfg, h, positions, mode="decode", cache=cache,
        index=index, moe_impl=moe_impl, mesh=mesh, constrain=constrain,
        data_axes=data_axes)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h, constrain)
    return logits, {"index": index + 1, "blocks": new_blocks}


def apply_prefill_paged(params, buffers, cfg, batch, pages, slot_mapping,
                        chunk_start=None, block_tables=None, prefix_lens=None,
                        block_size: int = 0, moe_impl="ragged", mesh=None,
                        constrain=_NOOP, data_axes=("data",)):
    """Prefill sequences (or chunks of them) into the paged pool.

    ``pages``: the pool's per-``p_pos`` stream dict (``PagedKVPool.pages``);
    ``slot_mapping`` [B,S] flat pool slots per prompt token (padding → the
    pool's out-of-bounds sentinel, dropped on write).

    One-shot mode (default): prompts start at position 0 and attend causally
    to themselves only.

    Chunked mode (``chunk_start`` given — a traced scalar or a per-lane [B]
    vector, so one jit covers every chunk *and* every batch composition):
    lane ``b``'s tokens sit at global positions ``chunk_start[b] + i``; RoPE
    is applied at those positions and attention additionally sees each lane's
    own already-cached prefix, located by ``block_tables`` [B,mb] /
    ``prefix_lens`` [B] / static ``block_size``.  Lanes whose chunk is fresh
    (``chunk_start == prefix_lens == 0``) reduce exactly to causal prefill,
    so mid-prefill chunks of *different* sequences — resumed or not — pack
    into one forward (batched chunked prefill, see docs/serving.md).
    → (logits [B,S,V], new_pages).
    """
    assert cfg.elitekv.enabled, "paged serving requires an EliteKV cache"
    h = _embed_inputs(params, cfg, batch, cfg.dtype)
    h = constrain("embed", h)
    S = h.shape[1]
    positions = jnp.arange(S)
    paged = {"slot_mapping": slot_mapping, "mesh": mesh}
    if chunk_start is not None:
        cs = jnp.asarray(chunk_start, jnp.int32)
        # scalar → [S] positions (PR-3 single-lane path); [B] → [B,S] per-lane
        positions = (positions + cs if cs.ndim == 0
                     else positions[None, :] + cs[:, None])
        paged.update(block_tables=block_tables, prefix_lens=prefix_lens,
                     block_size=block_size)
    h, aux, new_pages = _scan_blocks(
        params, buffers, cfg, h, positions, mode="prefill",
        cache={"blocks": pages}, moe_impl=moe_impl, mesh=mesh,
        constrain=constrain, data_axes=data_axes, paged=paged)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h, constrain), new_pages


def apply_decode_paged(params, buffers, cfg, batch, pages, slot_mapping,
                       block_tables, lengths, block_size: int,
                       use_kernel: bool = True, moe_impl="ragged", mesh=None,
                       constrain=_NOOP, data_axes=("data",),
                       sparse_topk: int = 0, sparse_recent: int = 0):
    """One decode step for every serving slot, reading/writing pool pages.

    ``lengths`` [B] int32: live length *including* this token (0 = idle lane);
    ``slot_mapping`` [B] flat write slot for the new token; ``block_tables``
    [B, max_blocks].  Shapes are slot-count-static, so one jit covers the
    whole serving run regardless of which lanes are live.
    ``sparse_topk > 0`` enables latent-space sparse decode (top-k blocks +
    ``sparse_recent`` newest; needs a ``block_summaries=True`` pool — see
    core/elite_attention.py::apply_decode_paged).
    → (logits [B,1,V], new_pages).
    """
    assert cfg.elitekv.enabled, "paged serving requires an EliteKV cache"
    h = _embed_step(params, cfg, batch)
    paged = {"slot_mapping": slot_mapping, "block_tables": block_tables,
             "lengths": lengths, "block_size": block_size,
             "use_kernel": use_kernel, "mesh": mesh,
             "sparse_topk": sparse_topk, "sparse_recent": sparse_recent}
    h, aux, new_pages = _scan_blocks(
        params, buffers, cfg, h, None, mode="decode",
        cache={"blocks": pages}, moe_impl=moe_impl, mesh=mesh,
        constrain=constrain, data_axes=data_axes, paged=paged)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h, constrain), new_pages


def apply_verify_paged(params, buffers, cfg, batch, pages, slot_mapping,
                       block_tables, q_offsets, lengths, block_size: int,
                       use_kernel: bool = True, moe_impl="ragged", mesh=None,
                       constrain=_NOOP, data_axes=("data",)):
    """Speculative-verify forward: score a ``W = k+1``-token window per lane
    (the pending token + ``k`` draft proposals) against its paged prefix in
    ONE call, writing the window's full-model compressed streams to the pool.

    batch["tokens"] [B,W]; ``q_offsets`` [B] global position of each lane's
    window row 0 (== that lane's cached prefix length); ``lengths`` [B] live
    length *including* the window (0 = dead lane); ``slot_mapping`` [B,W]
    flat write slots (pad → sentinel).  Logits row ``w`` of lane ``b`` is the
    full model's next-token distribution after window token ``w`` — rows
    ``0..k-1`` judge the draft proposals, row ``k`` samples the bonus token.
    Shapes are (slots, W)-static, so one jit covers the whole serving run.
    → (logits [B,W,V], new_pages).
    """
    assert cfg.elitekv.enabled, "paged serving requires an EliteKV cache"
    h = _embed_step(params, cfg, batch)
    paged = {"slot_mapping": slot_mapping, "block_tables": block_tables,
             "q_offsets": q_offsets, "lengths": lengths,
             "block_size": block_size, "use_kernel": use_kernel,
             "mesh": mesh, "verify": True}  # explicit dispatch tag, not
    h, aux, new_pages = _scan_blocks(      # key-presence sniffing
        params, buffers, cfg, h, None, mode="decode",
        cache={"blocks": pages}, moe_impl=moe_impl, mesh=mesh,
        constrain=constrain, data_axes=data_axes, paged=paged)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h, constrain), new_pages


def make_draft_params(params, cfg, draft_rank: int):
    """Draft-model weights for self-speculative decode: every EliteKV layer's
    joint up-projections ``bk``/``bv`` are projected onto their top
    ``draft_rank`` singular directions (``core.lrd.truncate_joint_rank``) —
    no new trained weights, identical pytree structure/shapes, so the draft
    runs through the same jitted decode step and reads/writes the same paged
    pool as the full model (``a_kv`` stays full-width: draft-written latents
    occupy the verify-compatible layout and are overwritten by the verify
    forward anyway).  ``draft_rank <= 0`` or ``>= d_ckv`` returns ``params``
    unchanged (the full-rank draft, acceptance ≡ 1)."""
    from repro.core import lrd
    assert cfg.elitekv.enabled, "speculative decode requires an EliteKV cache"
    if draft_rank <= 0 or draft_rank >= cfg.elitekv.d_ckv:
        return params                       # full-rank draft (any LRD kind)
    assert cfg.elitekv.lrd == "joint", \
        "draft truncation targets the joint low-rank factors"
    import numpy as np
    draft = jax.tree.map(lambda t: t, params)            # shallow leaf copy
    for p_key, blk in draft["blocks"].items():
        if "bk" not in blk.get("attn", {}):
            continue
        bk = np.asarray(blk["attn"]["bk"])               # [n_super, d_ckv, ...]
        bv = np.asarray(blk["attn"]["bv"])
        outs = [lrd.truncate_joint_rank(bk[s], bv[s], draft_rank)
                for s in range(bk.shape[0])]
        blk["attn"] = dict(blk["attn"])
        blk["attn"]["bk"] = jnp.stack([o[0] for o in outs])
        blk["attn"]["bv"] = jnp.stack([o[1] for o in outs])
    return draft


def capture_attn_inputs(params, buffers, cfg, batch, moe_impl="ragged", mesh=None):
    """Normed attention inputs per attention layer (for the RoPElite search).

    Returns dict {p_pos: [n_super, B, S, d]} restricted to attention positions.
    """
    h = _embed_inputs(params, cfg, batch, cfg.dtype)
    S = h.shape[1]
    positions = jnp.arange(S)
    _, _, caps = _scan_blocks(params, buffers, cfg, h, positions, mode="train",
                              moe_impl=moe_impl, mesh=mesh, capture=True)
    return caps


def loss_fn(params, buffers, cfg, batch, moe_impl="ragged", mesh=None,
            constrain=_NOOP, aux_weight: float = 0.01, data_axes=("data",)):
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    nv = batch["patch_embeds"].shape[1] if (
        cfg.frontend == "vision" and "patch_embeds" in batch) else 0
    if cfg.loss_chunk and labels.shape[1] % cfg.loss_chunk == 0 and nv == 0:
        # §Perf: sequence-chunked CE — logits for one S-chunk at a time
        # (never materializes the [B,S,V] fp32 logits or their cotangent;
        # per-chunk logits are rematerialized in the backward)
        h, aux = apply_train(params, buffers, cfg, batch, moe_impl, mesh,
                             constrain, data_axes=data_axes, return_hidden=True)
        B, S, _ = h.shape
        ck = cfg.loss_chunk
        n = S // ck
        hs = jnp.moveaxis(h.reshape(B, n, ck, -1), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, n, ck), 1, 0)
        ms = (jnp.moveaxis(mask.reshape(B, n, ck), 1, 0) if mask is not None
              else jnp.ones((n, B, ck), jnp.float32))

        @jax.checkpoint
        def chunk(carry, xs):
            h_c, l_c, m_c = xs
            logits_c = _logits(params, cfg, h_c, constrain)
            logz = jax.nn.logsumexp(logits_c.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(
                logits_c.astype(jnp.float32), l_c[..., None], axis=-1)[..., 0]
            nll, cnt = carry
            return (nll + jnp.sum((logz - gold) * m_c), cnt + jnp.sum(m_c)), None

        (nll, cnt), _ = jax.lax.scan(chunk, (0.0, 0.0), (hs, ls, ms))
        ce = nll / jnp.maximum(cnt, 1.0)
    else:
        logits, aux = apply_train(params, buffers, cfg, batch, moe_impl, mesh,
                                  constrain, data_axes=data_axes)
        if nv:
            logits = logits[:, nv:, :]
        ce = cross_entropy(logits, labels, mask)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
