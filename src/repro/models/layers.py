"""Shared layers: RMSNorm, SwiGLU MLP, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float | None = None, in_axis: int = 0):
    """Truncated-normal fan-in init (LLaMA-style)."""
    fan_in = shape[in_axis]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * scale)


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }


def mlp(params, x, constrain=lambda n, t: t):
    """SwiGLU feed-forward."""
    h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (x @ params["w_up"].astype(x.dtype))
    h = constrain("mlp_h", h)
    return h @ params["w_down"].astype(x.dtype)


def embed_init(key, vocab: int, d_model: int):
    return {"table": dense_init(key, (vocab, d_model), scale=0.02)}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    """Tied or untied LM head: x @ table^T, logits in fp32."""
    return (x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE, fp32.  logits [B,S,V], labels [B,S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
