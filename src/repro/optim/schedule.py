"""LR schedules: constant (paper's uptraining §4.1), cosine, and WSD
(warmup-stable-decay — MiniCPM's schedule, since that arch is assigned)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac * peak + (1 - floor_frac) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)
    return fn


def wsd(peak: float, warmup: int, stable: int, decay: int, floor_frac: float = 0.01):
    """MiniCPM warmup-stable-decay: linear warmup → flat → exp-ish decay."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = peak * (floor_frac ** t)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, peak, dec))
        return out.astype(jnp.float32)
    return fn


def get(name: str, **kw):
    return {"constant": constant, "cosine": cosine, "wsd": wsd}[name](**kw)
