"""AdamW with selectable moment precision — fp32 / bf16 / int8.

The int8 path (block-quantized first and second moments with per-row scales,
à la 8-bit Adam) is the distributed-optimization trick that makes the
480B-parameter arctic config fit the v5e HBM budget: moments drop from
8 bytes/param to ~2.03 bytes/param.  Moments are dequantized, updated, and
requantized inside the (jitted, sharded) update — the quantization error acts
as bounded noise on the moment estimates.

All state mirrors the parameter sharding (ZeRO: the optimizer update is
purely elementwise, so sharded params ⇒ sharded states, no extra collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95          # paper §4.1 training setup
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8
    # §Perf: process big stacked leaves in slices of this many layers via
    # lax.map — bounds the fp32 decode/update transients of the (possibly
    # int8-quantized) moments to chunk/L of the leaf instead of 3-4 full
    # fp32 copies of every parameter
    update_chunk: int = 0           # 0 = whole-leaf update


# --- int8 block quantization (per trailing-row absmax) ----------------------

def _quant(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    ax = -1 if x.ndim else None
    scale = jnp.max(jnp.abs(x), axis=ax, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dequant(qs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return qs["q"].astype(jnp.float32) * qs["s"]


def _encode(x, dtype: str):
    if dtype == "int8":
        return _quant(x)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _decode(x, dtype: str):
    if dtype == "int8":
        return _dequant(x)
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------

def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _encode(zeros(p), cfg.moment_dtype), params),
        "v": jax.tree.map(lambda p: _encode(zeros(p), cfg.moment_dtype), params),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state, params, lr, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    is_quant = cfg.moment_dtype == "int8"

    def upd_one(p, g, m_enc, v_enc):
        g = g.astype(jnp.float32)
        m = _decode(m_enc, cfg.moment_dtype) * b1 + (1 - b1) * g
        v = _decode(v_enc, cfg.moment_dtype) * b2 + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        newp = (p.astype(jnp.float32)
                - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), _encode(m, cfg.moment_dtype), _encode(v, cfg.moment_dtype)

    def upd(p, g, m_enc, v_enc):
        ck = cfg.update_chunk
        if ck and p.ndim >= 3 and p.shape[0] > ck and p.shape[0] % ck == 0:
            resh = lambda t: t.reshape((p.shape[0] // ck, ck) + t.shape[1:])
            args = (resh(p), resh(g), jax.tree.map(resh, m_enc), jax.tree.map(resh, v_enc))
            outs = jax.lax.map(lambda a: upd_one(*a), args)
            unr = lambda t: t.reshape((p.shape[0],) + t.shape[2:])
            return (unr(outs[0]), jax.tree.map(unr, outs[1]), jax.tree.map(unr, outs[2]))
        return upd_one(p, g, m_enc, v_enc)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    if is_quant:
        # m/v subtrees have {'q','s'} structure per param leaf
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    else:
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm}
