"""Sharding plan: TP / FSDP(ZeRO-3) / EP / SP rules for every param & activation.

Mesh axes (launch/mesh.py):
  single-pod  (16, 16)        →  ("data", "model")
  multi-pod   (2, 16, 16)     →  ("pod", "data", "model")

Parallelism mapping:
  * TP   — attention heads / FFN hidden / vocab sharded over "model".
  * FSDP — the non-TP dim of every large matrix additionally sharded over
           ("pod",)+("data",) (ZeRO-3; XLA all-gathers per scan step).
  * EP   — MoE experts over "model" via shard_map (models/moe.py), expert
           hidden dim ZeRO-3-sharded over the data axes.
  * SP   — sequence (Megatron-style) sharding of the residual stream over
           "model" between blocks; GSPMD turns the per-sublayer output
           all-reduce into reduce-scatter + all-gather pairs.
  * DP   — batch over ("pod",)+("data",); for batch-1 long-context decode the
           *cache sequence* dim shards over "data" instead (context
           parallelism — softmax reductions cross shards via psum).

Head padding: archs whose head count doesn't divide TP=16 (arctic 56,
minicpm 36) are padded with zero-init heads (56→64, 36→48) — the padded
model strictly contains the original (zero wo rows ⇒ identical function);
documented in DESIGN.md §assumptions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp: bool = True
    seq_parallel: bool = True

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def n_dp(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def dp(self):
        if not self.dp_axes:                 # tp-only serving submesh
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def plan_for_mesh(mesh: Mesh, fsdp: bool = True, seq_parallel: bool = True) -> MeshPlan:
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    return MeshPlan(mesh=mesh, dp_axes=dp_axes, fsdp=fsdp, seq_parallel=seq_parallel)


def pad_cfg_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad head counts up to the next TP multiple (zero-init extra heads)."""
    nh = cfg.n_heads
    nkv = cfg.n_kv_heads
    if nh % tp == 0:
        return cfg
    new_nh = -(-nh // tp) * tp
    if cfg.q_group == 1:
        new_nkv = new_nh                 # MHA: pad kv heads along
    else:
        new_nkv = nkv                    # GQA: keep kv heads, grow the group
        while new_nh % new_nkv:          # (arctic 56→64: group 7→8)
            new_nh += tp
    return dataclasses.replace(cfg, n_heads=new_nh, n_kv_heads=new_nkv,
                               d_head=cfg.head_dim)


# ---------------------------------------------------------------------------
# parameter specs (path-rule based)
# ---------------------------------------------------------------------------

def _spec_for(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
              plan: MeshPlan) -> P:
    tp = plan.tp_axis
    fsdp = plan.dp if plan.fsdp else None
    stacked = path.startswith("blocks/")
    name = path.split("/")[-1]
    div = lambda dim, n: dim % n == 0

    def with_stack(*spec):
        return P(None, *spec) if stacked else P(*spec)

    n_dp, ntp = plan.n_dp, plan.tp
    fs = lambda dim: fsdp if (fsdp and div(dim, n_dp)) else None
    tps = lambda dim: tp if div(dim, ntp) else None

    body = shape[1:] if stacked else shape
    if name == "table":                       # [V, d]
        return with_stack(tps(body[0]), fs(body[1]))
    if path.startswith("lm_head"):            # [d, V]
        return with_stack(fs(body[0]), tps(body[1]))
    if name in ("scale", "conv_b", "dt_b", "D"):
        if name in ("conv_b", "dt_b", "D"):   # [di]
            return with_stack(tps(body[0]))
        return with_stack(None)
    if "ffn/dense" in path:                   # arctic parallel MLP (shard_map specs)
        if name in ("w_gate", "w_up"):
            return with_stack(None, tps(body[1]))
        return with_stack(tps(body[0]), None)
    if "ffn" in path and name == "router":    # [d, E] (replicated for shard_map)
        return with_stack(None, None)
    if "ffn" in path and len(body) == 3 and name in ("w_gate", "w_up"):
        # MoE experts [E, d, f]: EP over model, ZeRO-3 over data on f
        return with_stack(tps(body[0]), None, fs(body[2]))
    if "ffn" in path and len(body) == 3 and name == "w_down":   # [E, f, d]
        return with_stack(tps(body[0]), fs(body[1]), None)
    if name in ("w_gate", "w_up"):            # dense MLP [d, f]
        return with_stack(fs(body[0]), tps(body[1]))
    if name == "w_down":                      # [f, d]
        return with_stack(tps(body[0]), fs(body[1]))
    if name == "wq":                          # [d, nh, dh]
        return with_stack(fs(body[0]), tps(body[1]), None)
    if name in ("wk", "wv", "wk_e"):          # [d, nkv, *]
        return with_stack(fs(body[0]), tps(body[1]), None)
    if name == "wo":                          # [nh, dh, d]
        return with_stack(tps(body[0]), None, fs(body[2]))
    if name in ("a_kv", "a_k", "a_v"):        # [d, d_c]
        return with_stack(fs(body[0]), None)
    if name in ("bk", "bv"):                  # [d_c, nkv, *]
        return with_stack(None, tps(body[1]), None)
    # --- mamba ---
    if name == "in_proj":                     # [d, 2di]
        return with_stack(fs(body[0]), tps(body[1]))
    if name == "conv_w":                      # [K, di]
        return with_stack(None, tps(body[1]))
    if name == "x_proj":                      # [di, dtr+2N]
        return with_stack(tps(body[0]), None)
    if name == "dt_w":                        # [dtr, di]
        return with_stack(None, tps(body[1]))
    if name == "A_log":                       # [di, N]
        return with_stack(tps(body[0]), None)
    if name == "out_proj":                    # [di, d]
        return with_stack(tps(body[0]), fs(body[1]))
    if name == "elite_freqs":                 # [nkv, r] buffer
        return with_stack(None, None)
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params, cfg: ModelConfig, plan: MeshPlan):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.shape, cfg, plan), params)


def param_shardings(params, cfg, plan):
    return jax.tree.map(plan.named, param_pspecs(params, cfg, plan),
                        is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(opt_state, params, cfg: ModelConfig, plan: MeshPlan, moment_dtype: str):
    pspecs = param_pspecs(params, cfg, plan)

    if moment_dtype == "int8":
        def mom(spec):
            # {'q': full shape spec, 's': last dim collapsed to 1 → unshard it}
            return {"q": spec, "s": P(*(tuple(spec)[:-1] + (None,)))}
        m = jax.tree.map(mom, pspecs, is_leaf=lambda x: isinstance(x, P))
    else:
        m = pspecs
    return {"step": P(), "m": m, "v": m}


# ---------------------------------------------------------------------------
# serving pool pages
# ---------------------------------------------------------------------------

def serving_page_pspecs(cfg: ModelConfig, plan: MeshPlan) -> Dict[str, P]:
    """PartitionSpecs for the paged serving pool's per-stream page arrays.

    Per page the pool stores ``k_e [n_super, n_slots, nkv, 2r]`` plus the
    latent stream(s) ``c``/``c_k``/``c_v`` ``[n_super, n_slots, d_c]`` and,
    when quantized, per-token f32 ``*_scale [n_super, n_slots]`` arrays
    (core/cache.py).  Only ``k_e`` has a head dim: it shards over the TP axis
    when ``nkv`` divides, mirroring the ``wk_e``/``bk``/``bv`` head sharding
    in :func:`_spec_for`.  The latent is head-*shared* (J-LRD), and scales
    are per-token, so both replicate — which is exactly what lets block ids,
    prefix hashes, COW copies, swap and int8 scales stay shard-invariant.
    """
    head = plan.tp_axis if (plan.tp > 1 and cfg.n_kv_heads % plan.tp == 0) else None
    specs: Dict[str, P] = {"k_e": P(None, None, head, None)}
    for name in ("c", "c_k", "c_v"):
        specs[name] = P()
    for name in ("k_e_scale", "c_scale", "c_k_scale", "c_v_scale"):
        specs[name] = P()
    # sparse-decode block summaries [n_super, num_blocks, d_c] (head-shared
    # latent space, f32): replicate — block selection is computed once per
    # step and must be shard-invariant for the bit-identity wall to hold
    for name in ("c_blkmean", "c_blkmax", "c_k_blkmean", "c_k_blkmax"):
        specs[name] = P()
    return specs


def serving_page_shardings(cfg: ModelConfig, plan: MeshPlan) -> Dict[str, NamedSharding]:
    return {k: plan.named(v) for k, v in serving_page_pspecs(cfg, plan).items()}


# ---------------------------------------------------------------------------
# inputs / cache / activations
# ---------------------------------------------------------------------------

def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, plan: MeshPlan) -> Dict[str, P]:
    B = shape.global_batch
    bshard = B % plan.n_dp == 0
    dp = plan.dp if bshard else None
    out = {}
    names = {"tokens": 2, "labels": 2, "frames": 3, "patch_embeds": 3}
    for name, nd in names.items():
        out[name] = P(dp, *([None] * (nd - 1)))
    return out


def cache_pspecs(cache, cfg: ModelConfig, plan: MeshPlan, batch: int,
                 seq_over_tp: bool = False) -> Any:
    """Cache sharding: batch over DP when divisible, else cache-sequence over
    "data" (context parallelism for the batch-1 long_500k cell).

    ``seq_over_tp`` (§Perf decode-v2): additionally shard the cache sequence
    over the otherwise-idle *model* axis — the attention softmax reduces
    across shards with two tiny psums per layer, and per-device cache
    memory/traffic drops by TP×."""
    bshard = batch % plan.n_dp == 0

    def spec(path, leaf):
        name = _path_str(path)
        nd = leaf.ndim
        if "index" in name:
            return P()
        if "conv" in name or "ssm" in name:
            # [L, B, K-1, di] / [L, B, di, N]
            di_axis = 3 if "conv" in name else 2
            s = [None] * nd
            if bshard:
                s[1] = plan.dp
            if leaf.shape[di_axis] % plan.tp == 0:
                s[di_axis] = plan.tp_axis
            return P(*s)
        # attention caches: [L, B, S, ...]
        s = [None] * nd
        if bshard:
            s[1] = plan.dp
            if seq_over_tp and leaf.shape[2] % plan.tp == 0:
                s[2] = plan.tp_axis             # decode-v2 context parallel
        elif leaf.shape[2] % plan.n_dp == 0:
            s[2] = plan.dp                      # sequence/context parallel
        # kv-head dim shards over model when divisible (k_e/k/v: dim 3)
        if s[2] is None and nd >= 4 and leaf.shape[3] % plan.tp == 0:
            s[3] = plan.tp_axis
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache)


def make_constrain(plan: MeshPlan, cfg: ModelConfig, seq_len: int, batch: int,
                   decode: bool = False, seq_over_tp: bool = False):
    """Activation-constraint hook for lm.apply (residual stream + logits)."""
    mesh = plan.mesh
    bshard = batch % plan.n_dp == 0
    dp = plan.dp if bshard else None
    sp = (plan.tp_axis if (plan.seq_parallel and not decode
                           and seq_len % plan.tp == 0) else None)

    tp = plan.tp_axis
    ntp = plan.tp
    # for batch-1 decode the cache sequence dim shards over data instead
    seq_dp = plan.dp if (not bshard and decode and seq_len % plan.n_dp == 0) else None
    if decode and seq_over_tp and bshard and seq_len % ntp == 0:
        seq_dp = tp  # decode-v2: cache-length tensors S-sharded over model

    def constrain(name: str, x):
        if mesh is None:
            return x
        if name in ("embed", "residual", "attn_out", "ffn_out", "attn_in_sharded"):
            # Megatron-SP: the carried residual stream lives S-sharded over
            # the TP axis; GSPMD places all-gather at sublayer entry and
            # reduce-scatter at sublayer exit.
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, sp, None)))
        if name == "attn_in":
            # gathered (full-S) bf16 normed input — pins the SP gather to the
            # *bf16* tensor (otherwise XLA may gather an f32 norm intermediate)
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, None, None)))
        if name == "logits":
            vp = tp if x.shape[-1] % ntp == 0 else None
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, None, vp)))
        if name in ("attn_q", "heads4", "attn_kv"):   # [B,S,heads,*]
            sdim = seq_dp if x.shape[1] > 1 else None
            hp = (tp if (x.shape[2] % ntp == 0 and sdim != tp) else None)
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, sdim, hp, None)))
        if name in ("mlp_h", "ssm_h"):         # [B,S,f|di] — hidden over TP
            hp = tp if x.shape[-1] % ntp == 0 else None
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, None, hp)))
        if name == "latent":                   # [B,S,d_c] — replicated latent
            sdim = seq_dp if x.shape[1] > 1 else None
            return jax.lax.with_sharding_constraint(
                x, plan.named(P(dp, sdim, None)))
        return x

    return constrain
