"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _decode_masked(q_e, q_lat, k_e, c_k, c_v, valid, q_group: int,
                   scale: float) -> jnp.ndarray:
    """Shared decode-attention core with an explicit key-validity mask
    ``valid [B, 1, S]``.  Both the dense and the sparse paged oracles route
    through here, so when their gathered arrays and masks are equal the
    outputs are *bitwise* equal — the sparse ``k >= n_blocks`` identity wall
    rests on this sharing."""
    B, nh, r2 = q_e.shape
    nkv = k_e.shape[2]
    S = k_e.shape[1]
    qe_g = q_e.reshape(B, nkv, q_group, r2)
    s_e = jnp.einsum("bhge,bkhe->bhgk", qe_g, k_e, preferred_element_type=jnp.float32)
    s_e = s_e.reshape(B, nh, S)
    s_lat = jnp.einsum("bhc,bkc->bhk", q_lat, c_k, preferred_element_type=jnp.float32)
    s = (s_e + s_lat) * scale
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible key (empty serving slots) attend to nothing →
    # zero output (softmax over an all-masked row would otherwise yield a
    # uniform p)
    p = jnp.where(jnp.any(valid, axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhk,bkc->bhc", p.astype(c_v.dtype), c_v)


def elite_decode_ref(q_e, q_lat, k_e, c_k, c_v, lengths, q_group: int,
                     scale: float) -> jnp.ndarray:
    """Absorbed EliteKV decode attention.

    q_e   [B, nh, 2r]   rotated elite query
    q_lat [B, nh, dc]   bk-absorbed non-elite query
    k_e   [B, S, nkv, 2r]  rotated elite key cache
    c_k   [B, S, dc]    latent cache (K side)
    c_v   [B, S, dc]    latent cache (V side; same array under J-LRD)
    lengths [B] int32   valid cache length per sequence
    →     [B, nh, dc]   latent attention output (pre bv/wo absorption)
    """
    S = k_e.shape[1]
    valid = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    return _decode_masked(q_e, q_lat, k_e, c_k, c_v, valid, q_group, scale)


def elite_decode_paged_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                           block_tables, lengths, q_group: int, scale: float,
                           block_size: int) -> jnp.ndarray:
    """Paged EliteKV decode attention: gather pages, then the dense oracle.

    k_e_pages  [n_slots, nkv, 2r]   flat paged elite-key stream
    c_k_pages  [n_slots, dc]        flat paged latent stream (K side)
    c_v_pages  [n_slots, dc]        flat paged latent stream (V side)
    block_tables [B, max_blocks] int32   per-sequence block chains (pad = 0)
    lengths    [B] int32            live tokens per sequence (0 = empty slot)
    n_slots = num_blocks · block_size; token t of logical position p lives in
    flat slot  block_tables[b, p // block_size] · block_size + p % block_size.
    →          [B, nh, dc]
    """
    B, mb = block_tables.shape

    def gather(pages):
        paged = pages.reshape((-1, block_size) + pages.shape[1:])
        return paged[block_tables].reshape((B, mb * block_size) + pages.shape[1:])

    return elite_decode_ref(q_e, q_lat, gather(k_e_pages), gather(c_k_pages),
                            gather(c_v_pages), lengths, q_group, scale)


def select_topk_blocks(q_lat, blk_mean, blk_max, block_tables, lengths,
                       block_size: int, num_sel: int, recent: int):
    """Score resident blocks in latent space, pick the winners + recent tail.

    q_lat   [B, nh, dc]            bk-absorbed query (ALL heads — selection
                                   must be shard-invariant under TP)
    blk_mean/blk_max [n_blocks, dc]  per-block latent summaries (valid-row
                                   masked mean / absmax, f32)
    block_tables [B, mb] int32; lengths [B] int32; ``num_sel`` = total
    selection width W (top-k + recent tail); ``recent`` newest resident
    blocks are always forced in.

    score_j = Σ_h q_lat·mean_j + |q_lat|·absmax_j — the mean term estimates
    the block's average logit, the absmax term upper-bounds its peak.

    Returns ``(sel_tables [B, W] int32 physical block ids,
    sel_counts [B, W] int32 valid rows per selected block)``.  Selected
    logical indices are sorted ASCENDING so the sparse kernels accumulate
    in dense chain order; with ``W >= n_chain`` the selection is exactly
    the full chain and sparse decode is bit-identical to dense.
    """
    B, mb = block_tables.shape
    bs = block_size
    n_chain = -(-lengths // bs)                              # ceil, [B]
    j = jnp.arange(mb, dtype=jnp.int32)[None, :]             # logical index
    mean = blk_mean[block_tables]                            # [B, mb, dc]
    amax = blk_max[block_tables]
    score = (jnp.einsum("bhc,bjc->bj", q_lat, mean,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhc,bjc->bj", jnp.abs(q_lat), amax,
                          preferred_element_type=jnp.float32))
    resident = j < n_chain[:, None]
    tail = resident & (j >= n_chain[:, None] - recent)
    score = jnp.where(resident, score, -1e30)
    score = jnp.where(tail, 1e30, score)                     # force recents
    sel = jax.lax.top_k(score, min(num_sel, mb))[1]          # [B, W]
    sel = jnp.sort(sel, axis=-1).astype(jnp.int32)
    sel_tables = jnp.take_along_axis(block_tables, sel, axis=1)
    sel_counts = jnp.clip(lengths[:, None] - sel * bs, 0, bs).astype(jnp.int32)
    return sel_tables, sel_counts


def _sparse_valid(sel_counts, block_size: int):
    """[B, W] per-block counts → [B, 1, W·bs] row-validity mask.  For the
    full chain this equals the dense ``pos < length`` mask elementwise."""
    B, W = sel_counts.shape
    offs = jnp.tile(jnp.arange(block_size, dtype=jnp.int32), W)   # [W·bs]
    counts = jnp.repeat(sel_counts, block_size, axis=1)           # [B, W·bs]
    return (offs[None, :] < counts)[:, None, :]


def elite_decode_sparse_paged_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                  sel_tables, sel_counts, q_group: int,
                                  scale: float, block_size: int) -> jnp.ndarray:
    """Sparse paged decode oracle: gather only the SELECTED blocks, then the
    shared masked core.  ``sel_tables/sel_counts [B, W]`` come from
    ``select_topk_blocks``; a count of 0 contributes nothing (pad = block 0).
    With the full chain selected the gathered arrays and mask equal the dense
    oracle's → bitwise-identical output."""
    B, W = sel_tables.shape

    def gather(pages):
        paged = pages.reshape((-1, block_size) + pages.shape[1:])
        return paged[sel_tables].reshape((B, W * block_size) + pages.shape[1:])

    valid = _sparse_valid(sel_counts, block_size)
    return _decode_masked(q_e, q_lat, gather(k_e_pages), gather(c_k_pages),
                          gather(c_v_pages), valid, q_group, scale)


def elite_verify_ref(q_e, q_lat, k_e, c_k, c_v, q_offsets, lengths,
                     q_group: int, scale: float) -> jnp.ndarray:
    """Multi-query absorbed EliteKV *verify* attention (speculative decode).

    A verify window is a resumed chunk of ``W`` tokens: lane ``b``'s query
    row ``w`` sits at global position ``q_offsets[b] + w`` and sees cache key
    ``j`` iff  ``j <= q_offsets[b] + w``  and  ``j < lengths[b]`` — the
    offset-causal mask of ``flash_prefill_ref`` applied in the compressed
    latent space of ``elite_decode_ref``.

    q_e   [B, W, nh, 2r]   rotated elite queries (one per window position)
    q_lat [B, W, nh, dc]   bk-absorbed non-elite queries
    k_e   [B, S, nkv, 2r]; c_k/c_v [B, S, dc]; q_offsets/lengths [B] int32
    →     [B, W, nh, dc]   latent outputs.  ``W == 1`` with
    ``q_offsets == lengths - 1`` reduces exactly to ``elite_decode_ref``;
    ``lengths == 0`` lanes output exact zeros.
    """
    B, W, nh, r2 = q_e.shape
    S, nkv = k_e.shape[1], k_e.shape[2]
    qe_g = q_e.reshape(B, W, nkv, q_group, r2)
    ql_g = q_lat.reshape(B, W, nkv, q_group, -1)
    s_e = jnp.einsum("bwhge,bkhe->bhgwk", qe_g, k_e,
                     preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bwhgc,bkc->bhgwk", ql_g, c_k,
                       preferred_element_type=jnp.float32)
    s = (s_e + s_lat) * scale                                # [B,nkv,G,W,S]
    kpos = jnp.arange(S)[None, None, :]
    mask = (kpos <= jnp.arange(W)[None, :, None]
            + q_offsets[:, None, None]) \
        & (kpos < lengths[:, None, None])                    # [B,W,S]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, :, None], p, 0.0)
    o = jnp.einsum("bhgwk,bkc->bwhgc", p.astype(c_v.dtype), c_v)
    return o.reshape(B, W, nh, -1)


def elite_verify_paged_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                           block_tables, q_offsets, lengths, q_group: int,
                           scale: float, block_size: int) -> jnp.ndarray:
    """Paged verify attention: gather each lane's block chain, then the dense
    multi-query oracle.  Same page layout as ``elite_decode_paged_ref``;
    q_e/q_lat [B, W, nh, *], q_offsets/lengths [B] → [B, W, nh, dc]."""
    B, mb = block_tables.shape

    def gather(pages):
        paged = pages.reshape((-1, block_size) + pages.shape[1:])
        return paged[block_tables].reshape((B, mb * block_size) + pages.shape[1:])

    return elite_verify_ref(q_e, q_lat, gather(k_e_pages), gather(c_k_pages),
                            gather(c_v_pages), q_offsets, lengths, q_group,
                            scale)


def dequantize_pages(k_e_pages, c_k_pages, c_v_pages,
                     k_e_scale, c_k_scale, c_v_scale):
    """Expand an int8 pool's streams to f32: ``row * per_slot_scale``
    (core/quant.py).  Pages [n_slots, ...], scales [n_slots] f32."""
    from repro.core.quant import dequantize
    return (dequantize(k_e_pages, k_e_scale),
            dequantize(c_k_pages, c_k_scale),
            dequantize(c_v_pages, c_v_scale))


def elite_decode_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              k_e_scale, c_k_scale, c_v_scale,
                              block_tables, lengths, q_group: int,
                              scale: float, block_size: int) -> jnp.ndarray:
    """Quantized-pool decode oracle: dequantize every slot, then the f32
    paged oracle.  The Pallas q8 kernel must match THIS exactly — its fused
    in-register dequant is algebraically the same multiply."""
    k_e, c_k, c_v = dequantize_pages(k_e_pages, c_k_pages, c_v_pages,
                                     k_e_scale, c_k_scale, c_v_scale)
    return elite_decode_paged_ref(q_e, q_lat, k_e, c_k, c_v, block_tables,
                                  lengths, q_group, scale, block_size)


def elite_decode_sparse_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, k_e_scale, c_k_scale,
                                     c_v_scale, sel_tables, sel_counts,
                                     q_group: int, scale: float,
                                     block_size: int) -> jnp.ndarray:
    """Quantized sparse decode oracle: dequantize every slot, then the f32
    sparse oracle — the same contract as ``elite_decode_paged_q8_ref``."""
    k_e, c_k, c_v = dequantize_pages(k_e_pages, c_k_pages, c_v_pages,
                                     k_e_scale, c_k_scale, c_v_scale)
    return elite_decode_sparse_paged_ref(q_e, q_lat, k_e, c_k, c_v,
                                         sel_tables, sel_counts, q_group,
                                         scale, block_size)


def elite_verify_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              k_e_scale, c_k_scale, c_v_scale,
                              block_tables, q_offsets, lengths, q_group: int,
                              scale: float, block_size: int) -> jnp.ndarray:
    """Quantized-pool verify oracle: dequantize, then the f32 paged verify
    oracle (same contract as ``elite_decode_paged_q8_ref``)."""
    k_e, c_k, c_v = dequantize_pages(k_e_pages, c_k_pages, c_v_pages,
                                     k_e_scale, c_k_scale, c_v_scale)
    return elite_verify_paged_ref(q_e, q_lat, k_e, c_k, c_v, block_tables,
                                  q_offsets, lengths, q_group, scale,
                                  block_size)


def flash_prefill_ref(q, k, v, q_group: int, scale: float,
                      q_offset=0, kv_lens=None) -> jnp.ndarray:
    """Causal attention oracle.  q [B,Sq,nh,dh], k/v [B,Sk,nkv,dh] → [B,Sq,nh,dh].

    ``q_offset`` shifts the causal diagonal (resumed prefill chunks): key j
    is visible to query i of lane b iff  j <= i + q_offset[b]  and
    j < kv_lens[b].  Scalars broadcast; per-lane [B] vectors let one batch
    hold chunks resumed from different sequences (batched chunked prefill).
    Queries with no visible key (kv_lens == 0 lanes) attend to nothing and
    output exact zeros, mirroring the length-0 decode semantics.
    """
    B, Sq, nh, dh = q.shape
    Sk, nkv = k.shape[1], k.shape[2]
    offs = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    lens = (jnp.full((B,), Sk, jnp.int32) if kv_lens is None
            else jnp.asarray(kv_lens, jnp.int32))
    qg = q.reshape(B, Sq, nkv, q_group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(Sk)[None, None, :]
    mask = (kpos <= jnp.arange(Sq)[None, :, None] + offs[:, None, None]) \
        & (kpos < lens[:, None, None])                       # [B,Sq,Sk]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, ..., None], p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, nh, dh)


def rope_elite_ref(x, positions, freqs) -> jnp.ndarray:
    """Per-head rotary on packed elite dims.

    x [B,S,H,2r], positions [S], freqs [H,r] → rotated x.
    """
    from repro.core.rope import apply_elite_rope
    return apply_elite_rope(x, positions, freqs)
