"""Fused EliteKV decode-attention Pallas kernel (the paper's serving hot-spot).

One pass over the *compressed* cache computes, per (batch, kv-head):

    s   = q_e · K_eᵀ + q_lat · C_kᵀ          (rotary-elite + latent scores)
    p   = online_softmax(s · scale)           (masked by per-sequence length)
    o   = p · C_v                             (latent output)

Decode attention is HBM-bandwidth-bound: the roofline is "read the cache
once".  Because this kernel reads only the 2r·n_kv + d_ckv compressed stream
(vs 2·d_h·n_kv uncompressed) its bandwidth roofline improves by exactly the
paper's compression ratio — and fusing both score paths means the latent C is
read once and serves s_lat *and* the output GEMM.

VMEM tiling: grid (B, n_kv, S/block_s); per step the working set is
  K_e block [block_s, 2r]  +  C_k/C_v blocks [block_s, d_c]
  + accumulators [G, d_c], [G, 1] ×2         (scratch, persists over S steps)
block_s=512, d_c=512, bf16 → ~1.1 MB ≪ 16 MB VMEM.  d_c and block_s are
128-multiples (MXU-aligned); the 2r rotary GEMM rides lane padding (≤64).
Per-sequence lengths arrive via scalar prefetch (ragged serving batches).

Final stage of the docs/architecture.md pipeline: the streams this kernel
reads are produced by RoPElite selection (core/ropelite.py) + J-LRD
factorization (core/lrd.py) and live in the paged pool docs/serving.md
describes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref,                  # scalar-prefetch [B] int32
            q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
            o_ref,
            acc_ref, m_ref, l_ref,
            *, block_s: int, scale: float, n_blocks: int):
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    start = sb * block_s

    @pl.when(start < length)
    def _step():
        q_e = q_e_ref[0, 0]                           # [G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [G, d_c]
        k_e = k_e_ref[0, :, 0, :]                     # [block_s, 2r]
        c_k = c_k_ref[0]                              # [block_s, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, block_s]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(c_v_ref.dtype), c_v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_decode(q_e, q_lat, k_e, c_k, c_v, lengths, q_group: int,
                 scale: float, block_s: int = 512, interpret: bool = False):
    """See kernels/ref.py::elite_decode_ref for exact semantics.

    q_e [B,nh,2r], q_lat [B,nh,d_c], k_e [B,S,nkv,2r], c_k/c_v [B,S,d_c],
    lengths [B] int32  →  o [B,nh,d_c]
    """
    B, nh, r2 = q_e.shape
    S, nkv = k_e.shape[1], k_e.shape[2]
    d_c = c_k.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    n_blocks = S // block_s

    q_e_g = q_e.reshape(B, nkv, G, r2)
    q_lat_g = q_lat.reshape(B, nkv, G, d_c)

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale, n_blocks=n_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, nkv, n_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, G, r2), lambda b, h, s, L: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, L: (b, h, 0, 0)),
                pl.BlockSpec((1, block_s, 1, r2), lambda b, h, s, L: (b, s, h, 0)),
                pl.BlockSpec((1, block_s, d_c), lambda b, h, s, L: (b, s, 0)),
                pl.BlockSpec((1, block_s, d_c), lambda b, h, s, L: (b, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, d_c), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, d_c), c_v.dtype),
        interpret=interpret,
        name="elite_decode",
    )(lengths, q_e_g, q_lat_g, k_e, c_k, c_v)
    return out.reshape(B, nh, d_c)


# ---------------------------------------------------------------------------
# paged decode: the cache lives in a block pool, sequences own block chains
# ---------------------------------------------------------------------------

def _paged_kernel(block_tables_ref,           # scalar-prefetch [B, mb] int32
                  lengths_ref,                # scalar-prefetch [B] int32
                  q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                  o_ref,
                  acc_ref, m_ref, l_ref,
                  *, block_size: int, scale: float, max_blocks: int):
    """Same online softmax as ``_kernel``; grid dim 2 walks the *block table*
    instead of a contiguous S axis — the BlockSpec index maps below pull page
    ``block_tables[b, sb]`` straight from the pool, so no gather ever
    materializes the sequence contiguously."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    start = sb * block_size

    @pl.when(start < length)
    def _step():
        q_e = q_e_ref[0, 0]                           # [G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [G, d_c]
        k_e = k_e_ref[0, :, 0, :]                     # [block_size, 2r]
        c_k = c_k_ref[0]                              # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(c_v_ref.dtype), c_v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == max_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_decode_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                       block_tables, lengths, q_group: int, scale: float,
                       block_size: int, interpret: bool = False):
    """See kernels/ref.py::elite_decode_paged_ref for exact semantics.

    q_e [B,nh,2r], q_lat [B,nh,d_c], k_e_pages [n_slots,nkv,2r],
    c_k/c_v_pages [n_slots,d_c], block_tables [B,mb] int32, lengths [B] int32
    →  o [B,nh,d_c].  Length-0 sequences (empty slots) produce zeros.
    """
    B, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    mb = block_tables.shape[1]
    assert block_tables.shape == (B, mb) and lengths.shape == (B,)

    q_e_g = q_e.reshape(B, nkv, G, r2)
    q_lat_g = q_lat.reshape(B, nkv, G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)

    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_size=block_size, scale=scale,
                          max_blocks=mb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nkv, mb),
            in_specs=[
                pl.BlockSpec((1, 1, G, r2), lambda b, h, s, bt, L: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, bt, L: (b, h, 0, 0)),
                # pool pages, indexed through the prefetched block table
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, bt, L: (bt[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, L: (bt[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, bt, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, d_c), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, d_c), c_v_pages.dtype),
        interpret=interpret,
        name="elite_decode_paged",
    )(block_tables, lengths, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p)
    return out.reshape(B, nh, d_c)


# ---------------------------------------------------------------------------
# paged decode over an int8 pool: fused in-register dequantization
# ---------------------------------------------------------------------------

def _paged_kernel_q8(block_tables_ref,        # scalar-prefetch [B, mb] int32
                     lengths_ref,             # scalar-prefetch [B] int32
                     q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                     k_s_ref, ck_s_ref, cv_s_ref,
                     o_ref,
                     acc_ref, m_ref, l_ref,
                     *, block_size: int, scale: float, max_blocks: int):
    """``_paged_kernel`` over int8 pages: the same block-table walk also pulls
    each page's per-slot f32 scales, and every stream is dequantized
    in-register (``int8 → f32 · scale``) right after the load — the HBM read
    stays one byte per element, the math stays f32."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    start = sb * block_size

    @pl.when(start < length)
    def _step():
        q_e = q_e_ref[0, 0]                           # [G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [G, d_c]
        k_s = k_s_ref[0]                              # [block_size]
        ck_s = ck_s_ref[0]
        k_e = k_e_ref[0, :, 0, :].astype(jnp.float32) \
            * k_s[:, None]                            # [block_size, 2r]
        c_k = c_k_ref[0].astype(jnp.float32) \
            * ck_s[:, None]                           # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        c_v = c_v_ref[0].astype(jnp.float32) * cv_s_ref[0][:, None]
        pv = jax.lax.dot_general(
            p, c_v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == max_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_decode_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                          k_e_scale, c_k_scale, c_v_scale,
                          block_tables, lengths, q_group: int, scale: float,
                          block_size: int, interpret: bool = False):
    """See kernels/ref.py::elite_decode_paged_q8_ref for exact semantics.

    Pages as in ``elite_decode_paged`` but int8; ``*_scale`` [n_slots] f32
    per-slot quantization scales.  Output is always f32 (the int8 pages must
    never leak their dtype into the attention output).
    """
    B, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    mb = block_tables.shape[1]
    assert block_tables.shape == (B, mb) and lengths.shape == (B,)

    q_e_g = q_e.astype(jnp.float32).reshape(B, nkv, G, r2)
    q_lat_g = q_lat.astype(jnp.float32).reshape(B, nkv, G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)
    k_s_p = k_e_scale.reshape(n_blocks_pool, block_size)
    ck_s_p = c_k_scale.reshape(n_blocks_pool, block_size)
    cv_s_p = c_v_scale.reshape(n_blocks_pool, block_size)

    out = pl.pallas_call(
        functools.partial(_paged_kernel_q8, block_size=block_size,
                          scale=scale, max_blocks=mb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nkv, mb),
            in_specs=[
                pl.BlockSpec((1, 1, G, r2), lambda b, h, s, bt, L: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, bt, L: (b, h, 0, 0)),
                # int8 pool pages + their per-slot scales, all indexed through
                # the same prefetched block table (one walk, two reads/page)
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, bt, L: (bt[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, L: (bt[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, L: (bt[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, L: (bt[b, s], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, bt, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, d_c), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, d_c), jnp.float32),
        interpret=interpret,
        name="elite_decode_paged_q8",
    )(block_tables, lengths, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p,
      k_s_p, ck_s_p, cv_s_p)
    return out.reshape(B, nh, d_c)


# ---------------------------------------------------------------------------
# sparse paged decode: walk a top-k SELECTION of blocks, not the whole chain
# ---------------------------------------------------------------------------

def _sparse_kernel(sel_tables_ref,            # scalar-prefetch [B, W] int32
                   sel_counts_ref,            # scalar-prefetch [B, W] int32
                   q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                   o_ref,
                   acc_ref, m_ref, l_ref,
                   *, scale: float, num_sel: int):
    """``_paged_kernel`` where grid dim 2 walks ``sel_tables`` — the top-k
    block selection from ``ref.py::select_topk_blocks`` — instead of the full
    block chain.  The length mask becomes a per-block row count
    (``sel_counts[b, sb]``; 0 skips the block entirely), so the kernel does
    O(k·block) work per token.  Selected blocks arrive in ascending chain
    order; with the full chain selected the walk, mask, and accumulation
    order equal the dense kernel's exactly (the bit-identity wall)."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    count = sel_counts_ref[b, sb]

    @pl.when(count > 0)
    def _step():
        q_e = q_e_ref[0, 0]                           # [G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [G, d_c]
        k_e = k_e_ref[0, :, 0, :]                     # [block_size, 2r]
        c_k = c_k_ref[0]                              # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(off < count, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(c_v_ref.dtype), c_v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == num_sel - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_decode_sparse_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              sel_tables, sel_counts, q_group: int,
                              scale: float, block_size: int,
                              interpret: bool = False):
    """See kernels/ref.py::elite_decode_sparse_paged_ref for exact semantics.

    Pages as in ``elite_decode_paged``; ``sel_tables [B, W]`` int32 physical
    block ids and ``sel_counts [B, W]`` int32 valid rows per selected block
    (0 ⇒ skip; all-0 lanes produce zeros) come from
    ``ref.py::select_topk_blocks``.  →  o [B,nh,d_c].
    """
    B, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    W = sel_tables.shape[1]
    assert sel_tables.shape == (B, W) and sel_counts.shape == (B, W)

    q_e_g = q_e.reshape(B, nkv, G, r2)
    q_lat_g = q_lat.reshape(B, nkv, G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)

    out = pl.pallas_call(
        functools.partial(_sparse_kernel, scale=scale, num_sel=W),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nkv, W),
            in_specs=[
                pl.BlockSpec((1, 1, G, r2), lambda b, h, s, st, ct: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, st, ct: (b, h, 0, 0)),
                # pool pages, indexed through the prefetched SELECTION table
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, st, ct: (st[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, st, ct: (st[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, st, ct: (st[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, st, ct: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, d_c), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, d_c), c_v_pages.dtype),
        interpret=interpret,
        name="elite_decode_sparse_paged",
    )(sel_tables, sel_counts, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p)
    return out.reshape(B, nh, d_c)


def _sparse_kernel_q8(sel_tables_ref,         # scalar-prefetch [B, W] int32
                      sel_counts_ref,         # scalar-prefetch [B, W] int32
                      q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                      k_s_ref, ck_s_ref, cv_s_ref,
                      o_ref,
                      acc_ref, m_ref, l_ref,
                      *, scale: float, num_sel: int):
    """``_sparse_kernel`` over int8 pages: the selection walk also pulls each
    page's per-slot f32 scales and dequantizes in-register, exactly like
    ``_paged_kernel_q8``."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    count = sel_counts_ref[b, sb]

    @pl.when(count > 0)
    def _step():
        q_e = q_e_ref[0, 0]                           # [G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [G, d_c]
        k_s = k_s_ref[0]                              # [block_size]
        ck_s = ck_s_ref[0]
        k_e = k_e_ref[0, :, 0, :].astype(jnp.float32) \
            * k_s[:, None]                            # [block_size, 2r]
        c_k = c_k_ref[0].astype(jnp.float32) \
            * ck_s[:, None]                           # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(off < count, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        c_v = c_v_ref[0].astype(jnp.float32) * cv_s_ref[0][:, None]
        pv = jax.lax.dot_general(
            p, c_v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == num_sel - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_decode_sparse_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                 k_e_scale, c_k_scale, c_v_scale,
                                 sel_tables, sel_counts, q_group: int,
                                 scale: float, block_size: int,
                                 interpret: bool = False):
    """See kernels/ref.py::elite_decode_sparse_paged_q8_ref for semantics.

    ``elite_decode_sparse_paged`` over int8 pages + per-slot f32 scales;
    output is always f32.
    """
    B, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    W = sel_tables.shape[1]
    assert sel_tables.shape == (B, W) and sel_counts.shape == (B, W)

    q_e_g = q_e.astype(jnp.float32).reshape(B, nkv, G, r2)
    q_lat_g = q_lat.astype(jnp.float32).reshape(B, nkv, G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)
    k_s_p = k_e_scale.reshape(n_blocks_pool, block_size)
    ck_s_p = c_k_scale.reshape(n_blocks_pool, block_size)
    cv_s_p = c_v_scale.reshape(n_blocks_pool, block_size)

    out = pl.pallas_call(
        functools.partial(_sparse_kernel_q8, scale=scale, num_sel=W),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nkv, W),
            in_specs=[
                pl.BlockSpec((1, 1, G, r2), lambda b, h, s, st, ct: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, st, ct: (b, h, 0, 0)),
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, st, ct: (st[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, st, ct: (st[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, st, ct: (st[b, s], 0, 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, st, ct: (st[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, st, ct: (st[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, st, ct: (st[b, s], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d_c), lambda b, h, s, st, ct: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, d_c), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, G, d_c), jnp.float32),
        interpret=interpret,
        name="elite_decode_sparse_paged_q8",
    )(sel_tables, sel_counts, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p,
      k_s_p, ck_s_p, cv_s_p)
    return out.reshape(B, nh, d_c)


# ---------------------------------------------------------------------------
# paged verify: k+1-token speculative windows, multi-query over the block table
# ---------------------------------------------------------------------------

def _verify_kernel(block_tables_ref,          # scalar-prefetch [B, mb] int32
                   q_offsets_ref,             # scalar-prefetch [B] int32
                   lengths_ref,               # scalar-prefetch [B] int32
                   q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                   o_ref,
                   acc_ref, m_ref, l_ref,
                   *, block_size: int, scale: float, max_blocks: int,
                   q_group: int):
    """``_paged_kernel`` generalized to ``window · G`` query rows per
    (batch, kv-head): row ``r`` holds window position ``w = r // G`` whose
    global query position is ``q_offsets[b] + w``, so the length mask gains
    the per-row offset-causal term of ``flash_prefill``'s diagonal —
    speculative verify scores all ``k+1`` window tokens in one block-table
    walk over the compressed cache."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    q_offset = q_offsets_ref[b]
    start = sb * block_size

    @pl.when(start < length)
    def _step():
        q_e = q_e_ref[0, 0]                           # [W·G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [W·G, d_c]
        k_e = k_e_ref[0, :, 0, :]                     # [block_size, 2r]
        c_k = c_k_ref[0]                              # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [W·G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qw = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // q_group
        s = jnp.where((pos <= q_offset + qw) & (pos < length), s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(c_v_ref.dtype), c_v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [W·G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == max_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_verify_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                       block_tables, q_offsets, lengths, q_group: int,
                       scale: float, block_size: int,
                       interpret: bool = False):
    """See kernels/ref.py::elite_verify_paged_ref for exact semantics.

    q_e [B,W,nh,2r], q_lat [B,W,nh,d_c], pages as in ``elite_decode_paged``,
    q_offsets [B] int32 (global position of each lane's window row 0),
    lengths [B] int32 (live tokens *including* the window; 0 = dead lane)
    →  o [B,W,nh,d_c].  Length-0 lanes produce zeros.
    """
    B, W, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    mb = block_tables.shape[1]
    assert block_tables.shape == (B, mb)
    assert q_offsets.shape == (B,) and lengths.shape == (B,)

    # row layout (w, g): row r of a (b, kv-head) tile is window position r // G
    q_e_g = q_e.reshape(B, W, nkv, G, r2).transpose(0, 2, 1, 3, 4) \
        .reshape(B, nkv, W * G, r2)
    q_lat_g = q_lat.reshape(B, W, nkv, G, d_c).transpose(0, 2, 1, 3, 4) \
        .reshape(B, nkv, W * G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)

    out = pl.pallas_call(
        functools.partial(_verify_kernel, block_size=block_size, scale=scale,
                          max_blocks=mb, q_group=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, nkv, mb),
            in_specs=[
                pl.BlockSpec((1, 1, W * G, r2),
                             lambda b, h, s, bt, off, L: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, W * G, d_c),
                             lambda b, h, s, bt, off, L: (b, h, 0, 0)),
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, W * G, d_c),
                                   lambda b, h, s, bt, off, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((W * G, d_c), jnp.float32),
                pltpu.VMEM((W * G, 1), jnp.float32),
                pltpu.VMEM((W * G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, W * G, d_c), c_v_pages.dtype),
        interpret=interpret,
        name="elite_verify_paged",
    )(block_tables, q_offsets, lengths, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p)
    return out.reshape(B, nkv, W, G, d_c).transpose(0, 2, 1, 3, 4) \
        .reshape(B, W, nh, d_c)


def _verify_kernel_q8(block_tables_ref,       # scalar-prefetch [B, mb] int32
                      q_offsets_ref,          # scalar-prefetch [B] int32
                      lengths_ref,            # scalar-prefetch [B] int32
                      q_e_ref, q_lat_ref, k_e_ref, c_k_ref, c_v_ref,
                      k_s_ref, ck_s_ref, cv_s_ref,
                      o_ref,
                      acc_ref, m_ref, l_ref,
                      *, block_size: int, scale: float, max_blocks: int,
                      q_group: int):
    """``_verify_kernel`` over int8 pages with fused in-register dequant —
    same W·G query-row layout and offset-causal mask, same per-slot scale
    loads as ``_paged_kernel_q8``."""
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    q_offset = q_offsets_ref[b]
    start = sb * block_size

    @pl.when(start < length)
    def _step():
        q_e = q_e_ref[0, 0]                           # [W·G, 2r]
        q_lat = q_lat_ref[0, 0]                       # [W·G, d_c]
        k_e = k_e_ref[0, :, 0, :].astype(jnp.float32) \
            * k_s_ref[0][:, None]                     # [block_size, 2r]
        c_k = c_k_ref[0].astype(jnp.float32) \
            * ck_s_ref[0][:, None]                    # [block_size, d_c]
        s = jax.lax.dot_general(
            q_e, k_e, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [W·G, block_size]
        s += jax.lax.dot_general(
            q_lat, c_k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qw = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // q_group
        s = jnp.where((pos <= q_offset + qw) & (pos < length), s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        c_v = c_v_ref[0].astype(jnp.float32) * cv_s_ref[0][:, None]
        pv = jax.lax.dot_general(
            p, c_v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # [W·G, d_c]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(sb == max_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def elite_verify_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                          k_e_scale, c_k_scale, c_v_scale,
                          block_tables, q_offsets, lengths, q_group: int,
                          scale: float, block_size: int,
                          interpret: bool = False):
    """See kernels/ref.py::elite_verify_paged_q8_ref for exact semantics.

    ``elite_verify_paged`` over int8 pages + per-slot f32 scales; output is
    always f32.
    """
    B, W, nh, r2 = q_e.shape
    nkv = k_e_pages.shape[1]
    d_c = c_k_pages.shape[-1]
    G = q_group
    assert nh == nkv * G, (nh, nkv, G)
    assert k_e_pages.shape[0] % block_size == 0, (k_e_pages.shape, block_size)
    n_blocks_pool = k_e_pages.shape[0] // block_size
    mb = block_tables.shape[1]
    assert block_tables.shape == (B, mb)
    assert q_offsets.shape == (B,) and lengths.shape == (B,)

    q_e_g = q_e.astype(jnp.float32).reshape(B, W, nkv, G, r2) \
        .transpose(0, 2, 1, 3, 4).reshape(B, nkv, W * G, r2)
    q_lat_g = q_lat.astype(jnp.float32).reshape(B, W, nkv, G, d_c) \
        .transpose(0, 2, 1, 3, 4).reshape(B, nkv, W * G, d_c)
    k_e_p = k_e_pages.reshape(n_blocks_pool, block_size, nkv, r2)
    c_k_p = c_k_pages.reshape(n_blocks_pool, block_size, d_c)
    c_v_p = c_v_pages.reshape(n_blocks_pool, block_size, d_c)
    k_s_p = k_e_scale.reshape(n_blocks_pool, block_size)
    ck_s_p = c_k_scale.reshape(n_blocks_pool, block_size)
    cv_s_p = c_v_scale.reshape(n_blocks_pool, block_size)

    out = pl.pallas_call(
        functools.partial(_verify_kernel_q8, block_size=block_size,
                          scale=scale, max_blocks=mb, q_group=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, nkv, mb),
            in_specs=[
                pl.BlockSpec((1, 1, W * G, r2),
                             lambda b, h, s, bt, off, L: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, W * G, d_c),
                             lambda b, h, s, bt, off, L: (b, h, 0, 0)),
                pl.BlockSpec((1, block_size, 1, r2),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, h, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size, d_c),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0, 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0)),
                pl.BlockSpec((1, block_size),
                             lambda b, h, s, bt, off, L: (bt[b, s], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, W * G, d_c),
                                   lambda b, h, s, bt, off, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((W * G, d_c), jnp.float32),
                pltpu.VMEM((W * G, 1), jnp.float32),
                pltpu.VMEM((W * G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nkv, W * G, d_c), jnp.float32),
        interpret=interpret,
        name="elite_verify_paged_q8",
    )(block_tables, q_offsets, lengths, q_e_g, q_lat_g, k_e_p, c_k_p, c_v_p,
      k_s_p, ck_s_p, cv_s_p)
    return out.reshape(B, nkv, W, G, d_c).transpose(0, 2, 1, 3, 4) \
        .reshape(B, W, nh, d_c)


def elite_verify_paged_xla(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                           block_tables, q_offsets, lengths, q_group: int,
                           scale: float, block_size: int):
    """Gather-based XLA fallback for the verify kernel (CPU / rejected
    shapes) — one gather of the compressed stream, then the dense multi-query
    oracle; identical semantics to the Pallas block-table walk."""
    from repro.kernels.ref import elite_verify_paged_ref
    return elite_verify_paged_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                  block_tables, q_offsets, lengths, q_group,
                                  scale, block_size)


def elite_decode_paged_xla(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                           block_tables, lengths, q_group: int, scale: float,
                           block_size: int):
    """Gather-based XLA fallback with identical semantics to the Pallas paged
    kernel (used on CPU and for shapes the TPU lowering rejects).  One gather
    materializes [B, mb·block_size] of the compressed stream — still only the
    2r·n_kv + d_ckv floats/token the paper pays for, never the full K/V."""
    from repro.kernels.ref import elite_decode_paged_ref
    return elite_decode_paged_ref(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                  block_tables, lengths, q_group, scale,
                                  block_size)


def elite_decode_paged_q8_xla(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              k_e_scale, c_k_scale, c_v_scale,
                              block_tables, lengths, q_group: int,
                              scale: float, block_size: int):
    """XLA fallback for the int8 paged decode kernel: dequantize the pool
    (one multiply) then the gather-based f32 fallback — exact oracle match."""
    from repro.kernels.ref import elite_decode_paged_q8_ref
    return elite_decode_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, k_e_scale, c_k_scale,
                                     c_v_scale, block_tables, lengths,
                                     q_group, scale, block_size)


def elite_verify_paged_q8_xla(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              k_e_scale, c_k_scale, c_v_scale,
                              block_tables, q_offsets, lengths, q_group: int,
                              scale: float, block_size: int):
    """XLA fallback for the int8 paged verify kernel."""
    from repro.kernels.ref import elite_verify_paged_q8_ref
    return elite_verify_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, k_e_scale, c_k_scale,
                                     c_v_scale, block_tables, q_offsets,
                                     lengths, q_group, scale, block_size)


def elite_decode_sparse_paged_xla(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                  sel_tables, sel_counts, q_group: int,
                                  scale: float, block_size: int):
    """Gather-based XLA fallback for the sparse decode kernel: gather only
    the [B, W·block_size] selected slots, then the shared masked oracle."""
    from repro.kernels.ref import elite_decode_sparse_paged_ref
    return elite_decode_sparse_paged_ref(q_e, q_lat, k_e_pages, c_k_pages,
                                         c_v_pages, sel_tables, sel_counts,
                                         q_group, scale, block_size)


def elite_decode_sparse_paged_q8_xla(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, k_e_scale, c_k_scale,
                                     c_v_scale, sel_tables, sel_counts,
                                     q_group: int, scale: float,
                                     block_size: int):
    """XLA fallback for the int8 sparse decode kernel."""
    from repro.kernels.ref import elite_decode_sparse_paged_q8_ref
    return elite_decode_sparse_paged_q8_ref(q_e, q_lat, k_e_pages, c_k_pages,
                                            c_v_pages, k_e_scale, c_k_scale,
                                            c_v_scale, sel_tables, sel_counts,
                                            q_group, scale, block_size)
