"""Causal flash-attention Pallas kernel (prefill / training forward).

Standard online-softmax tiling (FlashAttention adapted to TPU VMEM/MXU):
grid (B, n_heads, Sq/block_q, Sk/block_k), sequential over the kv axis with
fp32 accumulators in VMEM scratch.  Causal block-skipping via ``pl.when`` —
blocks strictly above the diagonal are never touched, halving HBM traffic.

GQA is handled by mapping each q-head to its kv head in the BlockSpec index
map (no materialized K/V repeat — the repeat would multiply HBM reads by the
group size).

Used at prefill for EliteKV models *after* the latent up-projection
materializes K = [K_e | c·bk] and V = c·bv for the current chunk; training
uses the same kernel via the materialized path.

Resumed chunks (chunked prefill, see docs/serving.md): a chunk of queries at
global positions ``q_offset .. q_offset+Sq`` attends to keys at positions
``0 .. Sk`` — the mask becomes ``kpos <= qpos + q_offset`` and the causal
block skip shifts by the same offset.  ``q_offset`` is static (one compile
per chunk/context shape).  NOTE: the paged serving loop currently resumes
chunks through the XLA gather path (``elite_attention._attend_resumed``);
wiring this kernel to the paged prefix via a contiguous gather scratch is
the TPU follow-up tracked in ROADMAP.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_q: int, block_k: int, scale: float, n_kb: int,
            q_offset: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal skip: kv block strictly above the (offset) diagonal
    @pl.when(jk * block_k <= iq * block_q + block_q - 1 + q_offset)
    def _step():
        q = q_ref[0, :, 0, :]                                # [bq, dh]
        k = k_ref[0, :, 0, :]                                # [bk, dh]
        v = v_ref[0, :, 0, :]                                # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos + q_offset, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == n_kb - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, q_group: int, scale: float,
                  block_q: int = 256, block_k: int = 512,
                  q_offset: int = 0, interpret: bool = False):
    """Causal attention.  q [B,Sq,nh,dh], k/v [B,Sk,nkv,dh] → [B,Sq,nh,dh].

    ``q_offset`` (static) shifts the causal diagonal: key ``j`` is visible to
    query ``i`` iff ``j <= i + q_offset``.  A resumed prefill chunk passes its
    start position so it attends to the whole cached prefix plus itself; the
    default 0 with Sq == Sk is ordinary causal attention.
    """
    B, Sq, nh, dh = q.shape
    Sk = k.shape[1]
    nkv = k.shape[2]
    assert nh == nkv * q_group
    assert q_offset >= 0 and Sk >= Sq + q_offset, (Sq, Sk, q_offset)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_qb, n_kb = Sq // block_q, Sk // block_k

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, n_kb=n_kb, q_offset=q_offset),
        grid=(B, nh, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, i, j, g=q_group: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, i, j, g=q_group: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, nh, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_prefill",
    )(q, k, v)
    return out
