"""Causal flash-attention Pallas kernel (prefill / training forward).

Standard online-softmax tiling (FlashAttention adapted to TPU VMEM/MXU):
grid (B, n_heads, Sq/block_q, Sk/block_k), sequential over the kv axis with
fp32 accumulators in VMEM scratch.  Causal block-skipping via ``pl.when`` —
blocks strictly above the diagonal are never touched, halving HBM traffic.

GQA is handled by mapping each q-head to its kv head in the BlockSpec index
map (no materialized K/V repeat — the repeat would multiply HBM reads by the
group size).

Used at prefill for EliteKV models *after* the latent up-projection
materializes K = [K_e | c·bk] and V = c·bv for the current chunk; training
uses the same kernel via the materialized path.

Resumed chunks (chunked prefill, see docs/serving.md): a chunk of queries at
global positions ``q_offsets[b] .. q_offsets[b]+Sq`` attends to keys at
positions ``0 .. Sk`` — the mask becomes ``kpos <= qpos + q_offsets[b]`` and
the causal block skip shifts by the same offset.  Offsets and key lengths are
**per-lane** scalar-prefetch vectors, so one call (and one compile) serves a
batch of chunks resumed from *different* sequences at different depths —
the batched-prefill contract of the serving scheduler.  ``kv_lens[b]`` masks
each lane's padded key tail (keys at ``kpos >= kv_lens[b]`` are invisible).
NOTE: the paged serving loop currently resumes chunks through the XLA gather
path (``elite_attention._attend_resumed``); wiring this kernel to the paged
prefix via a contiguous gather scratch is the TPU follow-up tracked in
ROADMAP.md.  With an int8 pool (``--pool-dtype int8``) that prefix gather
dequantizes each slot by its stored scale (``core/quant.py``) before the
bk/bv up-projection, so this kernel always sees f32/bf16 inputs — the
quantized representation never crosses the materialized-K/V boundary.

The same per-lane offset-causal contract powers speculative decode's verify
windows: a ``k+1``-token window is a resumed chunk whose queries sit at
``q_offsets[b] + w`` — ``kernels/elite_decode.py::elite_verify_paged``
applies exactly this mask in the *absorbed* latent space, walking the block
table directly instead of gathering (see docs/serving.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_offsets_ref, kv_lens_ref,     # scalar-prefetch [B] int32 each
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_q: int, block_k: int, scale: float, n_kb: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    q_offset = q_offsets_ref[b]
    kv_len = kv_lens_ref[b]

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal skip: kv block strictly above the (per-lane offset) diagonal,
    # or entirely past this lane's live keys
    visible = (jk * block_k <= iq * block_q + block_q - 1 + q_offset) \
        & (jk * block_k < kv_len)

    @pl.when(visible)
    def _step():
        q = q_ref[0, :, 0, :]                                # [bq, dh]
        k = k_ref[0, :, 0, :]                                # [bk, dh]
        v = v_ref[0, :, 0, :]                                # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kpos <= qpos + q_offset) & (kpos < kv_len), s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == n_kb - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, q_group: int, scale: float,
                  block_q: int = 256, block_k: int = 512,
                  q_offset=0, kv_lens=None, interpret: bool = False):
    """Causal attention.  q [B,Sq,nh,dh], k/v [B,Sk,nkv,dh] → [B,Sq,nh,dh].

    ``q_offset`` shifts the causal diagonal: key ``j`` is visible to query
    ``i`` of lane ``b`` iff ``j <= i + q_offset[b]`` and ``j < kv_lens[b]``.
    It is a python int (every lane shares the offset — ordinary causal
    attention at 0) or a per-lane [B] int32 vector: a *batch* of prefill
    chunks resumed from different sequences each passes its own start
    position.  ``kv_lens`` [B] (default Sk) masks per-lane padded key tails.
    Both ride scalar prefetch — one compile covers every offset/length mix.
    """
    B, Sq, nh, dh = q.shape
    Sk = k.shape[1]
    nkv = k.shape[2]
    assert nh == nkv * q_group
    if isinstance(q_offset, int):
        assert q_offset >= 0 and Sk >= Sq + q_offset, (Sq, Sk, q_offset)
    q_offsets = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32), (B,))
    kv_lens = (jnp.full((B,), Sk, jnp.int32) if kv_lens is None
               else jnp.asarray(kv_lens, jnp.int32))
    assert q_offsets.shape == (B,) and kv_lens.shape == (B,)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_qb, n_kb = Sq // block_q, Sk // block_k

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, n_kb=n_kb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nh, n_qb, n_kb),
            in_specs=[
                pl.BlockSpec((1, block_q, 1, dh),
                             lambda b, h, i, j, off, kl: (b, i, h, 0)),
                pl.BlockSpec((1, block_k, 1, dh),
                             lambda b, h, i, j, off, kl, g=q_group: (b, j, h // g, 0)),
                pl.BlockSpec((1, block_k, 1, dh),
                             lambda b, h, i, j, off, kl, g=q_group: (b, j, h // g, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, dh),
                                   lambda b, h, i, j, off, kl: (b, i, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, dh), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, nh, dh), v.dtype),
        interpret=interpret,
        name="flash_prefill",
    )(q_offsets, kv_lens, q, k, v)
    return out
