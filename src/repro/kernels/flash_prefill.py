"""Causal flash-attention Pallas kernel (prefill / training forward).

Standard online-softmax tiling (FlashAttention adapted to TPU VMEM/MXU):
grid (B, n_heads, S/block_q, S/block_k), sequential over the kv axis with
fp32 accumulators in VMEM scratch.  Causal block-skipping via ``pl.when`` —
blocks strictly above the diagonal are never touched, halving HBM traffic.

GQA is handled by mapping each q-head to its kv head in the BlockSpec index
map (no materialized K/V repeat — the repeat would multiply HBM reads by the
group size).

Used at prefill for EliteKV models *after* the latent up-projection
materializes K = [K_e | c·bk] and V = c·bv for the current chunk; training
uses the same kernel via the materialized path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_q: int, block_k: int, scale: float, n_kb: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal skip: kv block strictly above the diagonal
    @pl.when(jk * block_k <= iq * block_q + block_q - 1)
    def _step():
        q = q_ref[0, :, 0, :]                                # [bq, dh]
        k = k_ref[0, :, 0, :]                                # [bk, dh]
        v = v_ref[0, :, 0, :]                                # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == n_kb - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, q_group: int, scale: float,
                  block_q: int = 256, block_k: int = 512,
                  interpret: bool = False):
    """Causal attention.  q [B,S,nh,dh], k/v [B,S,nkv,dh] → [B,S,nh,dh]."""
    B, S, nh, dh = q.shape
    nkv = k.shape[2]
    assert nh == nkv * q_group
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_qb, n_kb = S // block_q, S // block_k

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, n_kb=n_kb),
        grid=(B, nh, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, i, j, g=q_group: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, i, j, g=q_group: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, nh, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_prefill",
    )(q, k, v)
    return out
