"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via ``interpret=True`` — the
kernel body runs in Python per grid step, numerically identical to the TPU
lowering.  On TPU backends they compile through Mosaic.

Observability (docs/observability.md): ``set_kernel_tracer`` arms opt-in
host-side spans around the public dispatches — each call is timed
``block_until_ready`` so the span covers the device work, and lands on the
``kernel`` track of the trace timeline.  Spans fire only on the *eager* path
(micro-benchmarks, oracle comparisons, direct calls): when a wrapper runs
inside an outer ``jax.jit`` trace its arguments are abstract ``Tracer``
values, the dispatch happens later inside XLA, and host-side timing would be
meaningless — those calls are detected and skipped.  Timing never changes
results (the same jitted computation runs either way), so traced and
untraced runs stay bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import elite_decode as _ed
from repro.kernels import flash_prefill as _fp
from repro.kernels import rope_elite as _re

_TRACER = None                               # module-level opt-in (obs.Tracer)


def set_kernel_tracer(tracer) -> None:
    """Install (or clear with ``None``) the tracer kernel dispatches report
    to.  Process-wide by design: kernel call sites sit below the scheduler
    and the benchmark harness, which should not thread a tracer through
    every signature."""
    global _TRACER
    _TRACER = tracer


def _span(name: str, *tensors):
    """Active kernel span, or None when tracing is off / the call is being
    traced by an outer jit (abstract arguments)."""
    if _TRACER is None or not _TRACER.enabled:
        return None
    if any(isinstance(t, jax.core.Tracer) for t in tensors):
        return None
    return _TRACER.span(name, track="kernel", cat="kernel",
                        shape=str(tuple(tensors[0].shape)))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("q_group", "scale", "block_s"))
def _elite_decode_jit(q_e, q_lat, k_e, c_k, c_v, lengths, q_group: int,
                      scale: float, block_s: int = 512):
    return _ed.elite_decode(q_e, q_lat, k_e, c_k, c_v, lengths, q_group,
                            scale, block_s=block_s, interpret=_interpret())


def elite_decode(q_e, q_lat, k_e, c_k, c_v, lengths, q_group: int,
                 scale: float, block_s: int = 512):
    sp = _span("elite_decode", q_e)
    if sp is None:
        return _elite_decode_jit(q_e, q_lat, k_e, c_k, c_v, lengths, q_group,
                                 scale, block_s)
    with sp:
        return jax.block_until_ready(_elite_decode_jit(
            q_e, q_lat, k_e, c_k, c_v, lengths, q_group, scale, block_s))


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_decode_paged_jit(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                            block_tables, lengths, q_group: int, scale: float,
                            block_size: int, force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_decode_paged_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables,
            lengths, q_group, scale, block_size)
    return _ed.elite_decode_paged(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables, lengths,
        q_group, scale, block_size, interpret=False)


def elite_decode_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                       block_tables, lengths, q_group: int, scale: float,
                       block_size: int, force_xla: bool = False):
    """Paged decode attention over the block pool.

    TPU: Pallas kernel walking the prefetched block table (zero gather).
    CPU / ``force_xla``: gather-based XLA fallback with identical semantics —
    interpret-mode Pallas loops the grid in Python, far too slow to serve with.
    """
    sp = _span("elite_decode_paged", q_e)
    if sp is None:
        return _elite_decode_paged_jit(q_e, q_lat, k_e_pages, c_k_pages,
                                       c_v_pages, block_tables, lengths,
                                       q_group, scale, block_size, force_xla)
    with sp:
        return jax.block_until_ready(_elite_decode_paged_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables,
            lengths, q_group, scale, block_size, force_xla))


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_decode_paged_q8_jit(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                               k_e_scale, c_k_scale, c_v_scale,
                               block_tables, lengths, q_group: int,
                               scale: float, block_size: int,
                               force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_decode_paged_q8_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, lengths, q_group, scale,
            block_size)
    return _ed.elite_decode_paged_q8(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale, c_k_scale,
        c_v_scale, block_tables, lengths, q_group, scale, block_size,
        interpret=False)


def elite_decode_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                          k_e_scale, c_k_scale, c_v_scale,
                          block_tables, lengths, q_group: int, scale: float,
                          block_size: int, force_xla: bool = False):
    """``elite_decode_paged`` over an int8 pool: the same block-table walk
    also loads each slot's f32 quantization scale and dequantizes in-register
    (core/quant.py).  Output is f32 regardless of page dtype.

    TPU: fused Pallas kernel.  CPU / ``force_xla``: dequantize-then-gather
    XLA fallback with identical semantics.
    """
    sp = _span("elite_decode_paged_q8", q_e)
    if sp is None:
        return _elite_decode_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, lengths, q_group, scale,
            block_size, force_xla)
    with sp:
        return jax.block_until_ready(_elite_decode_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, lengths, q_group, scale,
            block_size, force_xla))


@functools.partial(jax.jit,
                   static_argnames=("block_size", "num_sel", "recent"))
def select_topk_blocks(q_lat, blk_mean, blk_max, block_tables, lengths,
                       block_size: int, num_sel: int, recent: int):
    """Latent-space block selection for sparse decode — see
    kernels/ref.py::select_topk_blocks.  Runs OUTSIDE any tensor-parallel
    shard_map on the full-head ``q_lat`` so the selection is shard-invariant;
    its [B, W] outputs feed the sparse kernels as scalar prefetch."""
    from repro.kernels.ref import select_topk_blocks as _sel
    return _sel(q_lat, blk_mean, blk_max, block_tables, lengths, block_size,
                num_sel, recent)


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_decode_sparse_paged_jit(q_e, q_lat, k_e_pages, c_k_pages,
                                   c_v_pages, sel_tables, sel_counts,
                                   q_group: int, scale: float,
                                   block_size: int, force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_decode_sparse_paged_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, sel_tables,
            sel_counts, q_group, scale, block_size)
    return _ed.elite_decode_sparse_paged(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, sel_tables, sel_counts,
        q_group, scale, block_size, interpret=False)


def elite_decode_sparse_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                              sel_tables, sel_counts, q_group: int,
                              scale: float, block_size: int,
                              force_xla: bool = False):
    """Sparse paged decode attention: walk only the ``[B, W]`` selected
    blocks (``select_topk_blocks``) instead of the full chain — O(k·block)
    per token.  With the full chain selected the output is bit-identical to
    ``elite_decode_paged`` (the sparse recall wall, docs/serving.md).

    TPU: Pallas kernel walking the prefetched selection table.
    CPU / ``force_xla``: gather-based XLA fallback with identical semantics.
    """
    sp = _span("elite_decode_sparse_paged", q_e)
    if sp is None:
        return _elite_decode_sparse_paged_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, sel_tables,
            sel_counts, q_group, scale, block_size, force_xla)
    with sp:
        return jax.block_until_ready(_elite_decode_sparse_paged_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, sel_tables,
            sel_counts, q_group, scale, block_size, force_xla))


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_decode_sparse_paged_q8_jit(q_e, q_lat, k_e_pages, c_k_pages,
                                      c_v_pages, k_e_scale, c_k_scale,
                                      c_v_scale, sel_tables, sel_counts,
                                      q_group: int, scale: float,
                                      block_size: int, force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_decode_sparse_paged_q8_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, sel_tables, sel_counts, q_group, scale,
            block_size)
    return _ed.elite_decode_sparse_paged_q8(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale, c_k_scale,
        c_v_scale, sel_tables, sel_counts, q_group, scale, block_size,
        interpret=False)


def elite_decode_sparse_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                 k_e_scale, c_k_scale, c_v_scale,
                                 sel_tables, sel_counts, q_group: int,
                                 scale: float, block_size: int,
                                 force_xla: bool = False):
    """``elite_decode_sparse_paged`` over an int8 pool with fused in-register
    dequant; output is f32 regardless of page dtype."""
    sp = _span("elite_decode_sparse_paged_q8", q_e)
    if sp is None:
        return _elite_decode_sparse_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, sel_tables, sel_counts, q_group, scale,
            block_size, force_xla)
    with sp:
        return jax.block_until_ready(_elite_decode_sparse_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, sel_tables, sel_counts, q_group, scale,
            block_size, force_xla))


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_verify_paged_jit(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                            block_tables, q_offsets, lengths, q_group: int,
                            scale: float, block_size: int,
                            force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_verify_paged_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables,
            q_offsets, lengths, q_group, scale, block_size)
    return _ed.elite_verify_paged(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables, q_offsets,
        lengths, q_group, scale, block_size, interpret=False)


def elite_verify_paged(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                       block_tables, q_offsets, lengths, q_group: int,
                       scale: float, block_size: int, force_xla: bool = False):
    """Speculative-verify attention over the block pool: all ``k+1`` window
    positions of every lane scored in one pass of the compressed cache.

    ``q_offsets``/``lengths`` are per-lane scalar-prefetch vectors (the same
    machinery as ``flash_prefill``'s resumed chunks): lane ``b``'s window row
    ``w`` sits at global position ``q_offsets[b] + w`` and sees cache
    positions ``<= q_offsets[b] + w`` (offset-causal) below ``lengths[b]``.

    TPU: Pallas kernel walking the prefetched block table (zero gather).
    CPU / ``force_xla``: gather-based XLA fallback with identical semantics.
    """
    sp = _span("elite_verify_paged", q_e)
    if sp is None:
        return _elite_verify_paged_jit(q_e, q_lat, k_e_pages, c_k_pages,
                                       c_v_pages, block_tables, q_offsets,
                                       lengths, q_group, scale, block_size,
                                       force_xla)
    with sp:
        return jax.block_until_ready(_elite_verify_paged_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, block_tables,
            q_offsets, lengths, q_group, scale, block_size, force_xla))


@functools.partial(jax.jit,
                   static_argnames=("q_group", "scale", "block_size", "force_xla"))
def _elite_verify_paged_q8_jit(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                               k_e_scale, c_k_scale, c_v_scale,
                               block_tables, q_offsets, lengths, q_group: int,
                               scale: float, block_size: int,
                               force_xla: bool = False):
    if force_xla or _interpret():
        return _ed.elite_verify_paged_q8_xla(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, q_offsets, lengths, q_group,
            scale, block_size)
    return _ed.elite_verify_paged_q8(
        q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale, c_k_scale,
        c_v_scale, block_tables, q_offsets, lengths, q_group, scale,
        block_size, interpret=False)


def elite_verify_paged_q8(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                          k_e_scale, c_k_scale, c_v_scale,
                          block_tables, q_offsets, lengths, q_group: int,
                          scale: float, block_size: int,
                          force_xla: bool = False):
    """``elite_verify_paged`` over an int8 pool with fused in-register
    dequant — the speculative verify analogue of ``elite_decode_paged_q8``;
    output is f32 regardless of page dtype."""
    sp = _span("elite_verify_paged_q8", q_e)
    if sp is None:
        return _elite_verify_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, q_offsets, lengths, q_group,
            scale, block_size, force_xla)
    with sp:
        return jax.block_until_ready(_elite_verify_paged_q8_jit(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, k_e_scale,
            c_k_scale, c_v_scale, block_tables, q_offsets, lengths, q_group,
            scale, block_size, force_xla))


@functools.partial(jax.jit, static_argnames=("q_group", "scale", "block_q",
                                             "block_k"))
def _flash_prefill_jit(q, k, v, q_offsets, kv_lens, q_group: int, scale: float,
                       block_q: int, block_k: int):
    return _fp.flash_prefill(q, k, v, q_group, scale, block_q=block_q,
                             block_k=block_k, q_offset=q_offsets,
                             kv_lens=kv_lens, interpret=_interpret())


def flash_prefill(q, k, v, q_group: int, scale: float,
                  block_q: int = 256, block_k: int = 512, q_offset=0,
                  kv_lens=None):
    """``q_offset`` resumes prefill chunks against a longer key context
    (chunked prefill, see docs/serving.md): a python int applies one offset
    to every lane, a per-lane [B] vector packs chunks resumed from different
    sequences into one call.  ``kv_lens`` [B] masks per-lane key tails.
    Offsets/lengths are traced (scalar-prefetch), so one compile covers every
    batch composition."""
    B, Sk = q.shape[0], k.shape[1]
    if isinstance(q_offset, int):           # static path: validate the contract
        assert q_offset >= 0 and Sk >= q.shape[1] + q_offset, \
            (q.shape[1], Sk, q_offset)
    q_offsets = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    kv_lens = (jnp.full((B,), Sk, jnp.int32) if kv_lens is None
               else jnp.asarray(kv_lens, jnp.int32))
    bq, bk = min(block_q, q.shape[1]), min(block_k, Sk)
    sp = _span("flash_prefill", q)
    if sp is None:
        return _flash_prefill_jit(q, k, v, q_offsets, kv_lens, q_group, scale,
                                  bq, bk)
    with sp:
        return jax.block_until_ready(_flash_prefill_jit(
            q, k, v, q_offsets, kv_lens, q_group, scale, bq, bk))


@functools.partial(jax.jit, static_argnames=("block_s",))
def _rope_elite_jit(x, positions, freqs, block_s: int = 1024):
    return _re.rope_elite(x, positions, freqs, block_s=block_s,
                          interpret=_interpret())


def rope_elite(x, positions, freqs, block_s: int = 1024):
    sp = _span("rope_elite", x)
    if sp is None:
        return _rope_elite_jit(x, positions, freqs, block_s)
    with sp:
        return jax.block_until_ready(_rope_elite_jit(x, positions, freqs,
                                                     block_s))


# ---------------------------------------------------------------------------
# tensor-parallel shard_map wrappers (multi-device serving)
# ---------------------------------------------------------------------------
#
# The paged kernels treat heads as *batch* dims of their grid — no reduction
# ever crosses a head.  That makes head-sharding exact: each shard runs the
# ordinary dispatch on its head slice (and its kv-head slice of the k_e
# pages; the head-shared latent pages, per-token scales, block table and
# lengths are replicated), producing per-head outputs bitwise identical to
# the single-device call.  A tiled ``all_gather`` over the head axis then
# replicates the full pre-epilogue output ``o [..., nh, d_c]`` so the
# absorbed ``bv``/``wo`` epilogue — the only cross-head reduction in the
# decode path — runs replicated with single-device summation order.  (A
# ``psum_scatter`` epilogue fused into a head-sharded ``wo`` would halve the
# collective bytes but sums shard partials in a different float order;
# bit-identity to single-device is the serving wall, so the gather wins.
# docs/architecture.md#sharded-decode diagrams the data flow.)

from jax.sharding import PartitionSpec as _P


def _shard_map(fn, mesh, in_specs, out_specs):
    """Version-compat ``shard_map``: ``jax.shard_map`` where it exists, the
    ``jax.experimental`` spelling otherwise, with replication checking
    disabled under whichever keyword this jax spells it — the epilogue
    all_gather makes outputs replicated by construction, which the static
    checker cannot see through the inner jit call."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


def _rep(x) -> _P:
    return _P(*([None] * x.ndim))


def elite_decode_paged_tp(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, scales,
                          block_tables, lengths, q_group: int, scale: float,
                          block_size: int, mesh, tp_axis: str = "model",
                          force_xla: bool = False):
    """Tensor-parallel paged decode: ``q_e``/``q_lat [B, nh, *]`` sharded on
    heads, ``k_e_pages [n_slots, nkv, 2r]`` sharded on kv heads, everything
    else replicated; returns the replicated full-head ``o [B, nh, d_c]``.

    ``scales`` is ``None`` for an f32 pool or the
    ``(k_e_scale, c_k_scale, c_v_scale)`` triple for int8 — quantization is
    exact under head sharding because scales are per-token and dequant is
    elementwise."""
    if mesh.shape[tp_axis] == 1:
        if scales is None:
            return elite_decode_paged(q_e, q_lat, k_e_pages, c_k_pages,
                                      c_v_pages, block_tables, lengths,
                                      q_group, scale, block_size, force_xla)
        return elite_decode_paged_q8(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, *scales, block_tables,
                                     lengths, q_group, scale, block_size,
                                     force_xla)

    heads = _P(None, tp_axis, None)
    args = [q_e, q_lat, k_e_pages, c_k_pages, c_v_pages]
    specs = [heads, heads, _P(None, tp_axis, None),
             _rep(c_k_pages), _rep(c_v_pages)]
    if scales is not None:
        args += list(scales)
        specs += [_rep(s) for s in scales]
    args += [block_tables, lengths]
    specs += [_rep(block_tables), _rep(lengths)]

    def body(*xs):
        if scales is None:
            bq_e, bq_lat, k_e, c_k, c_v, bt, ln = xs
            o = elite_decode_paged(bq_e, bq_lat, k_e, c_k, c_v, bt, ln,
                                   q_group, scale, block_size, force_xla)
        else:
            bq_e, bq_lat, k_e, c_k, c_v, ks, cks, cvs, bt, ln = xs
            o = elite_decode_paged_q8(bq_e, bq_lat, k_e, c_k, c_v, ks, cks,
                                      cvs, bt, ln, q_group, scale, block_size,
                                      force_xla)
        return jax.lax.all_gather(o, tp_axis, axis=1, tiled=True)

    return _shard_map(body, mesh, tuple(specs), _P(None, None, None))(*args)


def elite_decode_sparse_paged_tp(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages,
                                 scales, sel_tables, sel_counts, q_group: int,
                                 scale: float, block_size: int, mesh,
                                 tp_axis: str = "model",
                                 force_xla: bool = False):
    """Tensor-parallel sparse paged decode.  Identical head-sharding contract
    to :func:`elite_decode_paged_tp`; ``sel_tables``/``sel_counts`` replace
    the block table + lengths and are REPLICATED — the selection was computed
    once on the full-head query (``select_topk_blocks``), so every shard
    walks the same blocks and the gathered output is bitwise identical to the
    single-device sparse call."""
    if mesh.shape[tp_axis] == 1:
        if scales is None:
            return elite_decode_sparse_paged(
                q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, sel_tables,
                sel_counts, q_group, scale, block_size, force_xla)
        return elite_decode_sparse_paged_q8(
            q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, *scales, sel_tables,
            sel_counts, q_group, scale, block_size, force_xla)

    heads = _P(None, tp_axis, None)
    args = [q_e, q_lat, k_e_pages, c_k_pages, c_v_pages]
    specs = [heads, heads, _P(None, tp_axis, None),
             _rep(c_k_pages), _rep(c_v_pages)]
    if scales is not None:
        args += list(scales)
        specs += [_rep(s) for s in scales]
    args += [sel_tables, sel_counts]
    specs += [_rep(sel_tables), _rep(sel_counts)]

    def body(*xs):
        if scales is None:
            bq_e, bq_lat, k_e, c_k, c_v, st, ct = xs
            o = elite_decode_sparse_paged(bq_e, bq_lat, k_e, c_k, c_v, st, ct,
                                          q_group, scale, block_size,
                                          force_xla)
        else:
            bq_e, bq_lat, k_e, c_k, c_v, ks, cks, cvs, st, ct = xs
            o = elite_decode_sparse_paged_q8(bq_e, bq_lat, k_e, c_k, c_v, ks,
                                             cks, cvs, st, ct, q_group, scale,
                                             block_size, force_xla)
        return jax.lax.all_gather(o, tp_axis, axis=1, tiled=True)

    return _shard_map(body, mesh, tuple(specs), _P(None, None, None))(*args)


def elite_verify_paged_tp(q_e, q_lat, k_e_pages, c_k_pages, c_v_pages, scales,
                          block_tables, q_offsets, lengths, q_group: int,
                          scale: float, block_size: int, mesh,
                          tp_axis: str = "model", force_xla: bool = False):
    """Tensor-parallel speculative verify: like :func:`elite_decode_paged_tp`
    but queries carry a window dim — ``q_e``/``q_lat [B, W, nh, *]`` shard on
    head axis 2 and the gather reassembles ``o [B, W, nh, d_c]``."""
    if mesh.shape[tp_axis] == 1:
        if scales is None:
            return elite_verify_paged(q_e, q_lat, k_e_pages, c_k_pages,
                                      c_v_pages, block_tables, q_offsets,
                                      lengths, q_group, scale, block_size,
                                      force_xla)
        return elite_verify_paged_q8(q_e, q_lat, k_e_pages, c_k_pages,
                                     c_v_pages, *scales, block_tables,
                                     q_offsets, lengths, q_group, scale,
                                     block_size, force_xla)

    heads = _P(None, None, tp_axis, None)
    args = [q_e, q_lat, k_e_pages, c_k_pages, c_v_pages]
    specs = [heads, heads, _P(None, tp_axis, None),
             _rep(c_k_pages), _rep(c_v_pages)]
    if scales is not None:
        args += list(scales)
        specs += [_rep(s) for s in scales]
    args += [block_tables, q_offsets, lengths]
    specs += [_rep(block_tables), _rep(q_offsets), _rep(lengths)]

    def body(*xs):
        if scales is None:
            bq_e, bq_lat, k_e, c_k, c_v, bt, qo, ln = xs
            o = elite_verify_paged(bq_e, bq_lat, k_e, c_k, c_v, bt, qo, ln,
                                   q_group, scale, block_size, force_xla)
        else:
            bq_e, bq_lat, k_e, c_k, c_v, ks, cks, cvs, bt, qo, ln = xs
            o = elite_verify_paged_q8(bq_e, bq_lat, k_e, c_k, c_v, ks, cks,
                                      cvs, bt, qo, ln, q_group, scale,
                                      block_size, force_xla)
        return jax.lax.all_gather(o, tp_axis, axis=2, tiled=True)

    return _shard_map(body, mesh, tuple(specs), _P(None, None, None, None))(*args)
