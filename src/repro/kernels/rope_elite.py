"""Per-head elite-RoPE Pallas kernel.

Rotates the packed elite dims of q/k with *per-head* frequency tables
(RoPElite permutes each head's elite chunks to the front, so the rotation is
a dense elementwise op on [S, 2r] — no gathers at runtime; the gather was
baked into the projection weights at conversion).

Grid (B, H); per step: x block [S_blk, 2r] + the head's freq row [1, r].
Pure VPU work fused into one pass (cos/sin computed in-kernel from positions
— no HBM-resident cos/sin tables).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, pos_ref, freq_ref, o_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)         # [Sb, 2r]
    pos = pos_ref[...].astype(jnp.float32)            # [Sb, 1]
    f = freq_ref[0]                                   # [r]
    ang = pos * f                                     # [Sb, r]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    Sb, r2 = x.shape
    xe = x.reshape(Sb, r2 // 2, 2)
    even, odd = xe[..., 0], xe[..., 1]
    out = jnp.stack([even * cos - odd * sin, even * sin + odd * cos], axis=-1)
    o_ref[0, :, 0, :] = out.reshape(Sb, r2).astype(o_ref.dtype)


def rope_elite(x, positions, freqs, block_s: int = 1024, interpret: bool = False):
    """x [B,S,H,2r], positions [S] int32, freqs [H,r] → rotated x."""
    B, S, H, r2 = x.shape
    r = r2 // 2
    assert freqs.shape == (H, r)
    block_s = min(block_s, S)
    assert S % block_s == 0
    pos2d = positions.reshape(S, 1).astype(jnp.float32)

    return pl.pallas_call(
        _kernel,
        grid=(B, H, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, 1, r2), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((block_s, 1), lambda b, h, s: (s, 0)),
            pl.BlockSpec((1, r), lambda b, h, s: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, 1, r2), lambda b, h, s: (b, s, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
        name="rope_elite",
    )(x, pos2d, freqs)
