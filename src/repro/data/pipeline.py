"""Token data pipeline: deterministic synthetic corpus + file-backed shards.

Design goals (the things that matter at 1000-node scale):
  * deterministic & resumable — iterator state is (epoch, step); restoring a
    checkpoint restores the exact batch stream, so restarts don't skew data.
  * per-host sharding — each data-parallel host reads only its slice
    (``host_id``/``num_hosts``); no coordinator.
  * loss masking + next-token shifting handled here, not in the model.

The synthetic corpus is a fixed-seed Zipf-ish Markov stream — enough
structure that perplexity falls during uptraining (benchmarks/fig6) while
remaining fully offline and reproducible.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int                  # per-host batch
    seed: int = 0
    kind: str = "synthetic"          # synthetic | file
    path: Optional[str] = None       # token shard dir for kind="file"
    host_id: int = 0
    num_hosts: int = 1


class SyntheticCorpus:
    """Markov-chain token stream with a Zipf marginal — deterministic."""

    def __init__(self, vocab: int, seed: int = 0, order_mix: float = 0.7):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        k = min(vocab, 64)
        # sparse transition structure: each token prefers k successors
        self.succ = rng.integers(0, vocab, size=(vocab, k))
        self.succ_p = rng.dirichlet(np.ones(k) * 0.5, size=vocab)
        self.zipf_p = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self.zipf_p /= self.zipf_p.sum()
        self.order_mix = order_mix

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        tok = int(rng.integers(0, self.vocab))
        for i in range(n):
            out[i] = tok
            if rng.random() < self.order_mix:
                j = rng.choice(self.succ.shape[1], p=self.succ_p[tok])
                tok = int(self.succ[tok, j])
            else:
                tok = int(rng.choice(self.vocab, p=self.zipf_p))
        return out


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    step: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class TokenPipeline:
    """Resumable batch iterator.

    Every (host, epoch, step) triple maps to one deterministic RNG stream, so
    resume == replay and elastic re-sharding (num_hosts change) only requires
    re-deriving host slices.
    """

    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.state = state or PipelineState()
        if cfg.kind == "synthetic":
            self.corpus = SyntheticCorpus(cfg.vocab_size, cfg.seed)
            self._shards = None
        else:
            self._shards = sorted(Path(cfg.path).glob("*.npy"))
            if not self._shards:
                raise FileNotFoundError(f"no .npy token shards under {cfg.path}")
            self.corpus = None

    # -- deterministic per-(host, epoch, step) randomness --
    def _rng(self) -> np.random.Generator:
        s = (self.cfg.seed * 1_000_003
             + self.state.epoch * 7_919
             + self.state.step * 104_729
             + self.cfg.host_id)
        return np.random.default_rng(s)

    def _tokens(self, rng) -> np.ndarray:
        B, L = self.cfg.batch_size, self.cfg.seq_len + 1
        if self.corpus is not None:
            return np.stack([self.corpus.sample(rng, L) for _ in range(B)])
        # file mode: random window reads from this host's shard slice
        shards = self._shards[self.cfg.host_id::self.cfg.num_hosts] or self._shards
        out = np.empty((B, L), np.int32)
        for b in range(B):
            arr = np.load(shards[int(rng.integers(len(shards)))], mmap_mode="r")
            start = int(rng.integers(0, max(1, len(arr) - L)))
            seg = np.asarray(arr[start:start + L], np.int32)
            if len(seg) < L:
                seg = np.pad(seg, (0, L - len(seg)), mode="wrap")
            out[b] = seg % self.cfg.vocab_size
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        rng = self._rng()
        toks = self._tokens(rng)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "loss_mask": jnp.ones((toks.shape[0], toks.shape[1] - 1), jnp.float32),
        }
        self.state.step += 1
        if self.state.step % 10_000 == 0:
            self.state.epoch += 1
        return batch


def write_token_shards(tokens: np.ndarray, out_dir: str, shard_size: int = 1 << 20):
    """Utility: dump a token array into .npy shards for kind="file"."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for i in range(0, len(tokens), shard_size):
        np.save(out / f"shard_{i // shard_size:05d}.npy",
                tokens[i:i + shard_size].astype(np.int32))
