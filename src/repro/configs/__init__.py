from repro.configs.base import (ARCH_IDS, SHAPES, EliteKVConfig, ModelConfig,
                                ShapeConfig, cell_applicable, get_config,
                                input_specs, list_archs, make_inputs)
