"""MiniCPM-2B [arXiv:2404.06395; hf]: 40L d=2304 36H kv=36 dff=5760 vocab=122753.

Llama-like arch; trained with the WSD schedule (implemented in optim/schedule.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm_2b", family="dense", num_layers=40, d_model=2304,
    n_heads=36, n_kv_heads=36, d_ff=5760, vocab_size=122753,
    tie_embeddings=True,
)
