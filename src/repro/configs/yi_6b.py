"""Yi-6B llama-arch GQA [arXiv:2403.04652; hf]: 32L d=4096 32H kv=4 dff=11008."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi_6b", family="dense", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64000,
    rope_theta=5000000.0,
)
