"""LLaMA2-7B [arXiv:2307.09288] — the paper's own primary model (MHA)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2_7b", family="dense", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab_size=32000,
)
