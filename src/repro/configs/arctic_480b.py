"""Snowflake Arctic base [hf:Snowflake/snowflake-arctic-base]:
35L d=7168 56H kv=8 MoE 128e top-2 dff=4864 + dense residual MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic_480b", family="moe", num_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_dff=4864, dense_residual=True,
)
