"""Config system: model architecture configs, input-shape cells, registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; the registry maps ``--arch <id>`` to it.  Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig`` objects;
``input_specs`` builds allocation-free ``jax.ShapeDtypeStruct`` stand-ins for
the dry-run, and ``make_inputs`` builds real (small) arrays for smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EliteKVConfig:
    """EliteKV (paper) hyper-parameters.

    ``elite_r``  — number of 2-D RoPE chunks kept (rotated) per KV head.
    ``d_ckv``    — rank of the joint low-rank latent (shared K/V cache dim);
                   kept 128-aligned per paper App. C "hardware friendly" rule.
    ``lrd``      — "joint" (J-LRD, paper's choice) or "separate" (S-LRD ablation).
    ``d_ck/d_cv``— S-LRD ranks (ignored for J-LRD).
    """

    enabled: bool = False
    elite_r: int = 8
    d_ckv: int = 512
    lrd: str = "joint"
    d_ck: int = 256
    d_cv: int = 256

    def cache_per_token_per_layer(self, n_kv: int, d_head: int) -> int:
        """Floats of cache per token per attention layer (paper §3.2)."""
        if not self.enabled:
            return 2 * n_kv * d_head
        rot = 2 * self.elite_r * n_kv
        if self.lrd == "joint":
            return rot + self.d_ckv
        return rot + self.d_ck + self.d_cv


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the unified decoder-only LM."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None     # explicit (qwen3 style); default d_model//n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dff: Optional[int] = None    # expert hidden dim (defaults to d_ff)
    dense_residual: bool = False     # arctic: parallel dense MLP + MoE
    moe_every: int = 1               # FFN of layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0               # mamba d_state (0 = no mamba layers)
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_period: int = 1             # hybrid: layer i is attention iff i % attn_period == attn_offset
    attn_offset: int = 0             # (attn_period=1 → all-attention; 0 attn layers for pure ssm)
    dt_rank: Optional[int] = None    # mamba Δ rank (default ceil(d_model/16))

    # --- frontends (stubs: precomputed embeddings) ---
    frontend: str = "none"           # none | audio | vision
    n_frontend_tokens: int = 0       # vision: number of patch tokens prepended

    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_chunk_q: Optional[int] = None   # None = auto (chunk at S >= 4096)
    attn_chunk_unroll: bool = False      # python-loop chunks (accurate HLO flops)
    ssm_chunk: int = 128                 # mamba scan chunk length
    ssm_unroll: bool = False             # python-loop mamba chunks
    scan_unroll: int = 1                 # lax.scan unroll factor (flop probing)
    loss_chunk: int = 0                  # seq-chunked CE (never materialize full logits)
    dtype: Any = jnp.float32
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"       # full (recompute block) | dots | none

    elitekv: EliteKVConfig = dataclasses.field(default_factory=EliteKVConfig)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256-multiple so the LM head TP-shards
        (Megatron-style padding; padded logit columns are masked in the loss)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def q_group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer index i."""
        if self.ssm_state == 0:
            return "attn"
        if self.attn_period <= 0:
            return "ssm"
        return "attn" if (i % self.attn_period == self.attn_offset and self.family != "ssm") else "ssm"

    def ffn_kind(self, i: int) -> str:
        """'moe', 'mlp' or 'none' for layer index i."""
        if self.d_ff == 0 and self.n_experts == 0:
            return "none"
        if self.n_experts > 0 and (i % self.moe_every == self.moe_offset):
            return "moe"
        return "mlp" if self.d_ff > 0 else "none"

    @property
    def block_period(self) -> int:
        """Smallest period after which (layer_kind, ffn_kind) repeats."""
        p = 1
        if self.ssm_state and self.attn_period > 1:
            p = np.lcm(p, self.attn_period)
        if self.n_experts and self.moe_every > 1:
            p = np.lcm(p, self.moe_every)
        return int(p)

    @property
    def attn_layer_indices(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.num_layers) if self.layer_kind(i) == "attn")

    @property
    def n_attn_layers(self) -> int:
        return len(self.attn_layer_indices)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, dh = self.d_model, self.head_dim
        n_vocab_mats = ((0 if self.frontend == "audio" else 1)
                        + (1 if (self.frontend == "audio" or not self.tie_embeddings) else 0))
        total = self.vocab_size * d * n_vocab_mats
        for i in range(self.num_layers):
            if self.layer_kind(i) == "attn":
                e = self.elitekv
                if e.enabled:
                    r2 = 2 * e.elite_r
                    total += d * self.n_heads * dh               # W^q
                    total += d * self.n_kv_heads * r2            # W^k elite
                    if e.lrd == "joint":
                        nope = self.n_kv_heads * (dh - r2)
                        total += d * e.d_ckv + e.d_ckv * (nope + self.n_kv_heads * dh)
                    else:
                        nope = self.n_kv_heads * (dh - r2)
                        total += d * e.d_ck + e.d_ck * nope
                        total += d * e.d_cv + e.d_cv * self.n_kv_heads * dh
                    total += self.n_heads * dh * d               # W^o
                else:
                    total += d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
                total += d  # attn norm
            else:  # mamba block
                di = self.d_inner
                dtr = self.dt_rank or -(-d // 16)
                total += d * 2 * di                    # in_proj (x, z)
                total += di * self.ssm_conv + di       # conv weight + bias
                total += di * (dtr + 2 * self.ssm_state)  # x_proj -> (dt, B, C)
                total += dtr * di + di                 # dt_proj
                total += di * self.ssm_state + di      # A_log, D
                total += di * d                        # out_proj
                total += d                             # norm
            fk = self.ffn_kind(i)
            if fk == "mlp":
                total += 3 * d * self.d_ff + d
            elif fk == "moe":
                mdff = self.moe_dff or self.d_ff
                total += self.n_experts * 3 * d * mdff + d * self.n_experts + d
                if self.dense_residual:
                    total += 3 * d * self.d_ff
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts) — for 6·N_active·D."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        mdff = self.moe_dff or self.d_ff
        total = self.param_count()
        for i in range(self.num_layers):
            if self.ffn_kind(i) == "moe":
                total -= (self.n_experts - self.top_k) * 3 * d * mdff
        return total

    def kv_cache_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Whole-model cache bytes per token (attn KV + mamba states amortized)."""
        total = 0
        for i in range(self.num_layers):
            if self.layer_kind(i) == "attn":
                total += self.elitekv.cache_per_token_per_layer(self.n_kv_heads, self.head_dim)
        return total * dtype_bytes

    def with_elitekv(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, elitekv=dataclasses.replace(self.elitekv, enabled=True, **kw))

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            num_layers=min(self.num_layers, 2 * self.block_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(4, self.n_kv_heads)),
            d_head=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_dff=128 if self.n_experts else None,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            elitekv=dataclasses.replace(
                self.elitekv, elite_r=4, d_ckv=64, d_ck=32, d_cv=32),
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) runs; long_500k skips pure full-attention archs."""
    if shape.name == "long_500k" and cfg.ssm_state == 0:
        return False, "long_500k skipped: pure full-attention arch (needs sub-quadratic path)"
    return True, ""


# ---------------------------------------------------------------------------
# Inputs: ShapeDtypeStructs for the dry-run, real arrays for smoke tests
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    For frontend archs the modality encoder is a stub: we hand the backbone
    precomputed frame/patch embeddings, per the assignment.
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.frontend == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        elif cfg.frontend == "vision":
            nv = cfg.n_frontend_tokens
            specs["patch_embeds"] = jax.ShapeDtypeStruct((B, nv, cfg.d_model), dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - nv), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S - nv), jnp.int32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        if cfg.frontend == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        elif cfg.frontend == "vision":
            nv = cfg.n_frontend_tokens
            specs["patch_embeds"] = jax.ShapeDtypeStruct((B, nv, cfg.d_model), dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - nv), jnp.int32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode: one new token against a cache of S
        if cfg.frontend == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return specs


def make_inputs(cfg: ModelConfig, batch: int, seq: int, kind: str, seed: int = 0) -> Dict[str, Any]:
    """Concrete small inputs for CPU smoke tests."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model), dtype=np.float32) * 0.02)
        if kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    elif cfg.frontend == "vision":
        nv = cfg.n_frontend_tokens
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, nv, cfg.d_model), dtype=np.float32) * 0.02)
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq - nv)), jnp.int32)
        if kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - nv)), jnp.int32)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        if kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "musicgen_large", "yi_6b", "minicpm_2b", "granite_3_2b", "tinyllama_1_1b",
    "internvl2_2b", "arctic_480b", "qwen3_moe_235b", "falcon_mamba_7b",
    "jamba_v0_1_52b", "llama2_7b", "llama2_13b",
)


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def list_archs():
    return ARCH_IDS
