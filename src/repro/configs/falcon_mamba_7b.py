"""Falcon-Mamba-7B [arXiv:2410.05355]: 64L d=4096 mamba1, state=16, attn-free.

EliteKV is INAPPLICABLE (no attention / no KV cache) — arch runs without the
technique per DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b", family="ssm", num_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, attn_period=0,
)
