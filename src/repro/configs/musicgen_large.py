"""MusicGen-large decoder backbone over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (kv=32 -> MHA) d_ff=8192 vocab=2048.  The EnCodec
frontend is a STUB: input_specs supplies precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_large", family="audio", num_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048,
    frontend="audio", rope_theta=10000.0,
)
