"""TinyLlama-1.1B [arXiv:2401.02385; hf]: 22L d=2048 32H kv=4 dff=5632."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama_1_1b", family="dense", num_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab_size=32000,
)
