"""LLaMA2-13B [arXiv:2307.09288] — the paper's scaling model (MHA)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2_13b", family="dense", num_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=13824, vocab_size=32000,
)
