"""Granite-3.0-2B base [hf:ibm-granite/granite-3.0-2b-base]: 40L d=2048 32H kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_3_2b", family="dense", num_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=49155,
    tie_embeddings=True,
)
