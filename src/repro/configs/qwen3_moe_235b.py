"""Qwen3-MoE-235B-A22B style [hf:Qwen/Qwen3-30B-A3B family]:
94L d=4096 64H (d_head=128) kv=4 MoE 128e top-8 expert dff=1536."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b", family="moe", num_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_head=128, d_ff=1536, vocab_size=151936,
    n_experts=128, top_k=8, moe_dff=1536, rope_theta=1000000.0,
)
