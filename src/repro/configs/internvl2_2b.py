"""InternVL2-2B [arXiv:2404.16821; hf]: InternLM2 backbone 24L d=2048 16H kv=8.

InternViT frontend is a STUB: input_specs supplies precomputed patch embeddings
(256 tokens) prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_2b", family="vlm", num_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553,
    frontend="vision", n_frontend_tokens=256,
)
