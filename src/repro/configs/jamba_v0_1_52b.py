"""Jamba-v0.1 [arXiv:2403.19887; hf]: 32L d=4096 32H kv=8 dff=14336,
Mamba:attn 7:1 interleave (attn at layer i%8==3), MoE 16e top-2 every 2nd layer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v0_1_52b", family="hybrid", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_dff=14336, moe_every=2, moe_offset=1,
    ssm_state=16, ssm_conv=4, ssm_expand=2, attn_period=8, attn_offset=3,
)
