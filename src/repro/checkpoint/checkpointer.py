"""Sharded, atomic, resumable checkpointing (orbax-free: npz shards + JSON
manifest) with elastic re-sharding across device counts.

Layout per step:
    <dir>/step_000123/
        manifest.json          — step, flat key list, shapes/dtypes, extra
        arrays_h000.npz        — this host's shard of every leaf
        _COMMITTED             — written last; a checkpoint without it is
                                 garbage (crash mid-write) and is ignored

Fault-tolerance contract:
  * save is atomic: write to step_xxx.tmp, fsync, rename, then _COMMITTED.
  * restore_latest() scans for the newest committed step — a training job
    that dies anywhere (including mid-save) restarts from the last good step.
  * keep_last bounds disk usage; older committed steps are pruned.
  * elastic: arrays are stored UNsharded per-leaf (gathered on save) so a
    restart may use a different mesh/device count; re-sharding happens at
    load via jax.device_put with the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3, host_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.host_id = host_id

    # ------------------------------------------------------------------
    def save(self, params, opt_state, extra: Dict[str, Any]):
        step = int(extra["step"])
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        tree = {"params": params, "opt": opt_state}
        flat = _flatten(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(tmp / f"arrays_h{self.host_id:03d}.npz", **arrays)
        manifest = {
            "step": step,
            "extra": {k: v for k, v in extra.items() if k != "step"},
            "keys": sorted(arrays.keys()),
            "treedef": None,
        }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        (final / "_COMMITTED").touch()
        self._prune()
        return final

    def _prune(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def committed_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "_COMMITTED").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def restore(self, step: int, like: Optional[Tuple] = None,
                shardings: Optional[Tuple] = None):
        """Restore (params, opt_state, extra).  ``like`` provides the target
        pytree structure; ``shardings`` (same structure) re-shards elastically."""
        d = self.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        data = np.load(d / f"arrays_h{self.host_id:03d}.npz")

        def rebuild(tree, shard_tree, prefix):
            flat = _flatten(tree)
            shards = _flatten(shard_tree) if shard_tree is not None else {}
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            keys = list(flat.keys())
            out = []
            for key, leaf in zip(keys, leaves):
                arr = data[f"{prefix}/{key}" if key else prefix]
                if shard_tree is not None and key in shards:
                    arr = jax.device_put(arr, shards[key])
                out.append(jax.numpy.asarray(arr) if not isinstance(arr, jax.Array) else arr)
            return jax.tree_util.tree_unflatten(treedef, out)

        if like is not None:
            params_like, opt_like = like
            sp, so = shardings if shardings is not None else (None, None)
            params = rebuild(params_like, sp, "params")
            opt = rebuild(opt_like, so, "opt")
        else:
            # structure-free restore: nested dict from flat keys
            params, opt = {}, {}
            for key in manifest["keys"]:
                root, rest = key.split("/", 1)
                tgt = params if root == "params" else opt
                parts = rest.split("/")
                cur = tgt
                for pp in parts[:-1]:
                    cur = cur.setdefault(pp, {})
                cur[parts[-1]] = jax.numpy.asarray(data[key])
        extra = dict(manifest["extra"], step=manifest["step"])
        return params, opt, extra

    def restore_latest(self, like=None, shardings=None):
        steps = self.committed_steps()
        if not steps:
            return None
        return self.restore(steps[-1], like=like, shardings=shardings)
