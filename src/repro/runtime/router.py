"""Data-parallel replica router: one front-end, N independent Schedulers.

The router owns the global serving clock and ``N`` `Scheduler` replicas, each
on its own device slice (``launch/mesh.py::replica_meshes`` — replicas never
synchronize; tensor parallelism lives *inside* a replica).  Every global step
it (1) routes due arrivals to the least-loaded replica, (2) steps every
replica once in lockstep, and (3) reconciles the :class:`ReplicaBoard`
admission ledger against observed scheduler state — the same ledger the
hypothesis op-fuzz in tests/test_property.py drives directly.

Token streams are router-invariant: greedy decoding is deterministic and the
sampled path folds ``PRNGKey(seed)`` with the per-request token count
(serve_loop.sample_tokens), so a request's output does not depend on which
replica — or slot, or step — it lands on.  That is the wall
tests/test_sharded_serving.py pins: dp=2 merged streams == single-scheduler
streams, bit for bit.

Observability: each replica's tracer events keep their shape but move to
``r{i}:``-prefixed tracks (counters gain an ``r{i}_`` name prefix) via
:class:`ReplicaTracer`, the router adds ``route`` instants and per-replica
occupancy counters on the ``router`` track, and the shared metrics registry
grows the name-encoded ``serve_replica_{i}_*`` family (the registry has no
labels by design — tools/check_trace.py validates the family all-or-nothing).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.runtime.serve_loop import Request, Scheduler, SchedulerConfig, ServeReport

REPLICA_METRIC_SUFFIXES = (
    "submitted_total",   # requests routed to this replica
    "completed_total",   # requests finished on this replica
    "waiting",           # gauge: queue depth after the last step
    "resident",          # gauge: occupied slots after the last step
    "blocks_used",       # gauge: pool blocks in use after the last step
)


class ReplicaBoard:
    """Pure per-replica admission ledger — the router's routing state and the
    op-fuzz target of tests/test_property.py.

    Requests move ``route → waiting → (admit) → resident → (retire)`` with
    ``preempt`` bouncing resident back to waiting.  The conservation law

        sum(waiting) + sum(resident) == submitted - retired

    holds after *every* operation; :meth:`check` asserts it (the router calls
    it each global step after reconciling observed scheduler deltas, so a
    bookkeeping leak fails loudly in production, not just under hypothesis).
    """

    def __init__(self, n: int):
        assert n >= 1, n
        self.n = n
        self.waiting = [0] * n
        self.resident = [0] * n
        self.routed = [0] * n        # lifetime admissions (imbalance metric)
        self.submitted = 0
        self.retired = 0

    def load(self, i: int) -> int:
        return self.waiting[i] + self.resident[i]

    def pick(self) -> int:
        """Least-loaded replica, lowest id on ties (deterministic)."""
        return min(range(self.n), key=lambda i: (self.load(i), i))

    def route(self, i: int) -> None:
        self.waiting[i] += 1
        self.routed[i] += 1
        self.submitted += 1

    def admit(self, i: int) -> None:
        assert self.waiting[i] > 0, (i, self.waiting)
        self.waiting[i] -= 1
        self.resident[i] += 1

    def preempt(self, i: int) -> None:
        assert self.resident[i] > 0, (i, self.resident)
        self.resident[i] -= 1
        self.waiting[i] += 1

    def retire(self, i: int) -> None:
        assert self.resident[i] > 0, (i, self.resident)
        self.resident[i] -= 1
        self.retired += 1

    def check(self) -> None:
        assert all(w >= 0 for w in self.waiting), self.waiting
        assert all(r >= 0 for r in self.resident), self.resident
        in_flight = sum(self.waiting) + sum(self.resident)
        assert in_flight == self.submitted - self.retired, \
            (self.waiting, self.resident, self.submitted, self.retired)

    def imbalance(self) -> float:
        """max/min lifetime admissions across replicas that saw traffic
        (1.0 = perfectly even).  Replicas with zero admissions are excluded:
        early in a run (or with fewer requests than replicas) some replicas
        legitimately have not been routed to yet, and folding them in made
        the metric inf — which poisoned every downstream mean and JSON
        export.  No traffic anywhere reports 1.0, not 0/0."""
        active = [r for r in self.routed if r > 0]
        if not active:
            return 1.0
        return max(active) / min(active)


class ReplicaTracer:
    """Per-replica view of a shared Tracer: same event stream, but tracks are
    prefixed ``r{i}:`` and counter names ``r{i}_`` so N replicas' timelines
    coexist in one trace without colliding (diagnose trace-summary groups the
    ``r{i}_pool_blocks_used`` counters back into per-replica sparklines)."""

    def __init__(self, base, i: int):
        self._base = base
        self._p = f"r{i}"

    @property
    def enabled(self) -> bool:
        return self._base.enabled

    @property
    def emitted(self) -> int:
        return self._base.emitted

    @property
    def dropped(self) -> int:
        return self._base.dropped

    def _t(self, track: str) -> str:
        return f"{self._p}:{track}"

    def instant(self, name, track="scheduler", cat="event", **args):
        return self._base.instant(name, self._t(track), cat, **args)

    def begin(self, name, track="scheduler", cat="event", **args):
        return self._base.begin(name, self._t(track), cat, **args)

    def end(self, name, track="scheduler", cat="event", **args):
        return self._base.end(name, self._t(track), cat, **args)

    def span(self, name, track="scheduler", cat="span", **args):
        return self._base.span(name, self._t(track), cat, **args)

    def counter(self, name, value, track="scheduler", cat="counter"):
        return self._base.counter(f"{self._p}_{name}", value,
                                  self._t(track), cat)

    def format_tail(self, n: int = 30) -> str:
        return self._base.format_tail(n)


@dataclasses.dataclass
class RouterReport:
    """Merged end-of-run view over every replica's ServeReport."""
    replicas: List[ServeReport]
    routed: List[int]                      # requests per replica
    completed: int = 0
    decoded_tokens: int = 0
    prefill_tokens: int = 0
    preemptions: int = 0
    wall_s: float = 0.0
    tok_per_s: float = 0.0                 # merged throughput (one wall clock)
    ttft_wall_p50_ms: float = 0.0          # percentiles over ALL requests
    ttft_wall_p95_ms: float = 0.0
    imbalance: float = 1.0                 # max/min routed (ReplicaBoard)

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def summary(self) -> str:
        return (f"dp={self.n_replicas} completed={self.completed} "
                f"decoded={self.decoded_tokens} tok/s={self.tok_per_s:.1f} "
                f"ttft_ms p50/p95={self.ttft_wall_p50_ms:.0f}/"
                f"{self.ttft_wall_p95_ms:.0f} "
                f"routed={self.routed} imbalance={self.imbalance:.2f}")

    def per_replica_table(self) -> str:
        """One line per replica: admissions, phase breakdown, occupancy."""
        lines = []
        for i, (n, rep) in enumerate(zip(self.routed, self.replicas)):
            lines.append(f"  r{i}: routed={n} completed={rep.completed} "
                         f"decoded={rep.decoded_tokens} "
                         f"occ={rep.mean_occupancy:.2f} "
                         f"preempt={rep.preemptions} | {rep.phase_table()}")
        return "\n".join(lines)


class Router:
    """Front-end over ``num_replicas`` independent Schedulers (see module
    docstring).  ``meshes`` optionally gives each replica its own (tensor-
    parallel) submesh — ``None`` entries serve that replica single-device."""

    def __init__(self, params, buffers, cfg, scfg: SchedulerConfig,
                 num_replicas: int, meshes: Optional[List[Any]] = None,
                 moe_impl: str = "ragged", tracer=None, metrics=None):
        assert num_replicas >= 1, num_replicas
        meshes = meshes if meshes is not None else [None] * num_replicas
        assert len(meshes) == num_replicas, (len(meshes), num_replicas)
        self.trace = tracer or NULL_TRACER
        self.metrics = metrics or MetricsRegistry()
        self.scfg = scfg
        # Replicas share params/buffers (host-side pytrees; jit replicates
        # them onto each replica's devices) and the metrics registry — shared
        # counters become fleet totals, while the serve_replica_{i}_* family
        # below keeps the per-replica split.
        self.replicas = [
            Scheduler(params, buffers, cfg, scfg, mesh=meshes[i],
                      moe_impl=moe_impl,
                      tracer=ReplicaTracer(self.trace, i),
                      metrics=self.metrics)
            for i in range(num_replicas)]
        self.board = ReplicaBoard(num_replicas)
        self.t = 0
        self._m: List[Dict[str, Any]] = []
        for i in range(num_replicas):
            self._m.append({
                "submitted_total": self.metrics.counter(
                    f"serve_replica_{i}_submitted_total",
                    f"requests routed to replica {i}"),
                "completed_total": self.metrics.counter(
                    f"serve_replica_{i}_completed_total",
                    f"requests finished on replica {i}"),
                "waiting": self.metrics.gauge(
                    f"serve_replica_{i}_waiting",
                    f"replica {i} queue depth"),
                "resident": self.metrics.gauge(
                    f"serve_replica_{i}_resident",
                    f"replica {i} occupied slots"),
                "blocks_used": self.metrics.gauge(
                    f"serve_replica_{i}_blocks_used",
                    f"replica {i} pool blocks in use"),
            })

    # -- routing ------------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route one request to the least-loaded replica; returns its id."""
        i = self.board.pick()
        self.board.route(i)
        self.replicas[i].submit(req)
        self._m[i]["submitted_total"].inc()
        self.trace.instant("route", track="router", cat="request",
                           uid=req.uid, replica=i,
                           load=self.board.load(i) - 1)
        return i

    # -- lockstep serving loop ---------------------------------------------
    def _step_replica(self, i: int) -> bool:
        """Step replica ``i`` once on the global clock and reconcile the
        board: admit/preempt/retire op counts are reconstructed exactly from
        the scheduler's observable state deltas (waiting moves only via those
        three ops), so the ledger stays event-accurate without hooks inside
        the scheduler."""
        rep = self.replicas[i]
        w0 = self.board.waiting[i]
        f0 = len(rep.finished)
        rep.t = self.t                       # lockstep: router owns the clock
        s0 = time.perf_counter()
        before = rep._measured_phase_ms()
        alive = rep.step()
        # mirror Scheduler.run's per-step wall accounting (the router drives
        # step() directly): residual host time lands in phase "other" so each
        # replica's sum(phase_ms) still equals its step_wall_ms_total
        dt_ms = (time.perf_counter() - s0) * 1e3
        rep._step_wall_ms_total += dt_ms
        other = dt_ms - (rep._measured_phase_ms() - before)
        rep._phase_ms["other"] += max(0.0, other)
        rep._m_phase["other"].inc(max(0.0, other))
        w1 = len(rep.waiting)
        r1 = sum(1 for s in rep.slots if s is not None)
        retires = len(rep.finished) - f0
        # Only the NET waiting flow (admits − preempts) is observable from
        # outside; applying it as all-admits or all-preempts lands the ledger
        # on the exact live state either way (asserted below).
        net = w0 - w1
        admits, preempts = (net, 0) if net >= 0 else (0, -net)
        for _ in range(admits):
            self.board.admit(i)
        for _ in range(preempts):
            self.board.preempt(i)
        for _ in range(retires):
            self.board.retire(i)
        assert self.board.waiting[i] == w1 and self.board.resident[i] == r1, \
            (i, self.board.waiting, self.board.resident, w1, r1)
        if retires:
            self._m[i]["completed_total"].inc(retires)
        self._m[i]["waiting"].set(w1)
        self._m[i]["resident"].set(r1)
        self._m[i]["blocks_used"].set(rep.pool.allocator.num_used)
        self.trace.counter(f"replica{i}_blocks_used",
                           rep.pool.allocator.num_used, track="router")
        self.trace.counter(f"replica{i}_resident", r1, track="router")
        return alive

    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> RouterReport:
        pending = deque(sorted(requests or [],
                               key=lambda r: (r.arrival, r.uid)))
        t0 = time.perf_counter()
        steps = 0
        while True:
            while pending and pending[0].arrival <= self.t:
                self.submit(pending.popleft())
            alive = False
            for i in range(len(self.replicas)):
                alive |= self._step_replica(i)
            self.board.check()
            if not alive and not pending:
                break
            self.t += 1
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"router stuck after {max_steps} steps: "
                    f"pending={len(pending)} board={self.board.__dict__}")
        return self.report(time.perf_counter() - t0)

    # -- merged report ------------------------------------------------------
    def report(self, wall_s: float) -> RouterReport:
        reps = [r.report(wall_s) for r in self.replicas]
        fin = [req for r in self.replicas for req in r.finished]
        ttft_ms = [(req.first_token_wall - req.submit_wall) * 1e3
                   for req in fin]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        decoded = sum(r.decoded_tokens for r in reps)
        return RouterReport(
            replicas=reps, routed=list(self.board.routed),
            completed=sum(r.completed for r in reps),
            decoded_tokens=decoded,
            prefill_tokens=sum(r.prefill_tokens for r in reps),
            preemptions=sum(r.preemptions for r in reps),
            wall_s=wall_s, tok_per_s=decoded / max(wall_s, 1e-9),
            ttft_wall_p50_ms=pct(ttft_ms, 50),
            ttft_wall_p95_ms=pct(ttft_ms, 95),
            imbalance=self.board.imbalance())

    def finished_tokens(self) -> Dict[int, List[int]]:
        """uid → generated tokens, merged across replicas (the identity the
        sharded-serving wall compares against a single scheduler)."""
        out: Dict[int, List[int]] = {}
        for rep in self.replicas:
            for req in rep.finished:
                assert req.uid not in out, req.uid
                out[req.uid] = list(req.generated)
        return out
