"""Training step factory + loop: grad accumulation, remat, sharded AdamW,
optional int8 gradient compression (error-feedback), checkpoint/restart.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    lr: float = 3e-4
    schedule: Optional[Callable] = None          # step → lr (overrides lr)
    grad_accum: int = 1                          # microbatch steps per update
    moe_impl: str = "ragged"
    grad_compression: bool = False               # int8 all-reduce w/ error feedback
    aux_weight: float = 0.01


def _compress_grads(grads, err):
    """int8 quantize grads + error feedback residual (beyond-paper trick:
    gradient compression for cross-pod reduction).  Returns (g_hat, new_err)."""
    from repro.optim.adamw import _dequant, _quant

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q = _quant(g)
        g_hat = _dequant(q)
        return g_hat, g - g_hat

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def make_train_step(cfg: ModelConfig, tc: TrainConfig, mesh=None,
                    constrain=None, data_axes=("data",)):
    """Returns train_step(params, buffers, opt_state, batch) →
    (params, opt_state, metrics).  Pure function of its inputs — jit/shard
    outside (launch/train.py, launch/dryrun.py)."""
    constrain = constrain or (lambda name, x: x)
    sched = tc.schedule or (lambda s: jnp.asarray(tc.lr, jnp.float32))

    def loss_fn(params, buffers, batch):
        return lm.loss_fn(params, buffers, cfg, batch, moe_impl=tc.moe_impl,
                          mesh=mesh, constrain=constrain,
                          aux_weight=tc.aux_weight, data_axes=data_axes)

    def train_step(params, buffers, opt_state, batch):
        if tc.grad_accum > 1:
            # microbatch over the leading batch dim
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, buffers, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((tc.grad_accum, -1) + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tc.grad_accum, gsum)
            loss = lsum / tc.grad_accum
            metrics = {"ce": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, buffers, batch)

        if tc.grad_compression:
            err = opt_state.get("err")
            grads, new_err = _compress_grads(grads, err)
        lr = sched(opt_state["step"])
        new_params, new_opt, om = adamw.update(grads, opt_state, params, lr,
                                               tc.optimizer)
        if tc.grad_compression:
            new_opt["err"] = new_err
        metrics = dict(metrics, loss=loss, lr=lr, **om)
        return new_params, new_opt, metrics

    return train_step


def init_opt_state(params, tc: TrainConfig):
    st = adamw.init(params, tc.optimizer)
    if tc.grad_compression:
        st["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return st


def train(params, buffers, cfg: ModelConfig, tc: TrainConfig, data_iter,
          num_steps: int, checkpointer=None, ckpt_every: int = 0,
          log_every: int = 50, mesh=None, callback=None):
    """Single-host training loop with checkpoint/restart support."""
    step_fn = jax.jit(make_train_step(cfg, tc, mesh=mesh))
    opt_state = init_opt_state(params, tc)
    start = 0
    if checkpointer is not None:
        restored = checkpointer.restore_latest()
        if restored is not None:
            params, opt_state, extra = restored
            start = int(extra["step"])
            # fast-forward the data stream so restart == uninterrupted run
            if hasattr(data_iter, "state"):
                data_iter.state.step += start      # O(1) seek (TokenPipeline)
            else:
                for _ in range(start):
                    next(data_iter)
    history = []
    for step in range(start, num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, buffers, opt_state, batch)
        if log_every and (step % log_every == 0 or step == num_steps - 1):
            history.append((step, float(metrics["loss"])))
        if callback is not None:
            callback(step, metrics)
        if checkpointer is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            checkpointer.save(params, opt_state, {"step": step + 1})
    return params, opt_state, history
