"""Serving runtime over the compressed EliteKV cache (see docs/serving.md).

Two tiers:

* ``generate`` — lockstep batched greedy decoding with a contiguous cache
  (examples / parity oracle).
* ``Scheduler`` — continuous batching over the block-paged pool
  (``core.cache.PagedKVPool`` + ``core.cache.BlockManager``): requests queue
  with arrival times, get admitted into free *slots* mid-flight, prefill
  their prompts in fixed-size token **chunks** interleaved with decode steps
  (so a long arriving prompt never stalls resident sequences), and retire on
  EOS or token budget — their blocks recycle immediately.  Each scheduler
  step packs up to ``prefill_batch_lanes`` mid-prefill sequences' chunks
  (``prefill_chunk_tokens`` each) into **one** padded forward — per-lane
  ``chunk_start`` / ``prefix_lens`` vectors let resumed chunks of different
  sequences attend to their own paged prefixes in the same call — then runs
  one decode step over all ``max_slots`` lanes (idle and still-prefilling
  lanes are masked by length 0).  With ``prefill_chunk_tokens=0`` the whole
  prompt is prefilled at admission in one call (PR-2 behaviour).  The run
  compiles once per prompt-length bucket (one-shot), once for the fixed
  batched chunk shape (chunked), plus once for decode.

Decoding samples per request: temperature / nucleus (top-p) with a
per-request PRNG seed, applied batched over all lanes in one jitted call;
``temperature=0`` lanes reduce exactly to greedy argmax.

Admission (``admission="preempt"``, the default) holds nothing back: a
request is admitted as soon as its next allocation fits, residents grow
blocks on demand, and when the pool runs dry mid-flight the scheduler
**preempts the youngest resident** — frees its blocks and requeues it at the
head of the waiting line for a recompute-prefill of its already-generated
prefix (``eviction="recompute"``), or copies its cached streams to host
memory and restores them block-exactly on re-admission
(``eviction="swap"``).  Token streams are invariant under preemption: a
recomputed prefix reproduces the exact logits the interrupted decode step
would have seen, and the count-folded sampling PRNG re-draws the exact same
token.  ``admission="watermark"`` keeps the legacy reservation policy
(worst-case remaining blocks of every resident held back, so growth can
never fail) for comparison runs — it trades occupancy for never preempting.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, mesh=None, constrain=None,
                      moe_impl: str = "ragged", data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def prefill_step(params, buffers, batch, cache):
        return lm.apply_prefill(params, buffers, cfg, batch, cache,
                                moe_impl=moe_impl, mesh=mesh,
                                constrain=constrain, data_axes=data_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, constrain=None,
                     moe_impl: str = "ragged", greedy: bool = True,
                     data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def decode_step(params, buffers, tokens, cache):
        batch = ({"tokens": tokens} if cfg.frontend != "audio"
                 else {"frames": tokens})
        logits, cache = lm.apply_decode(params, buffers, cfg, batch, cache,
                                        moe_impl=moe_impl, mesh=mesh,
                                        constrain=constrain, data_axes=data_axes)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    """Counters for the lockstep ``generate`` path.

    ``prefill_tokens``  — prompt tokens pushed through the prefill forward
                          (batch × prompt length).
    ``decoded_tokens``  — tokens produced by decode steps (batch × new tokens).
    ``cache_bytes``     — measured bytes of the attention KV cache actually
                          allocated for the run (the paper's headline
                          compression shows up here).
    """
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_bytes: int = 0


def generate(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
             max_new_tokens: int, mesh=None, moe_impl: str = "ragged",
             cache_dtype=jnp.float32) -> Tuple[np.ndarray, ServeStats]:
    """Greedy generation for a batch of fixed-length prompts (examples/tests).

    prompts: [B, S_prompt] int32 → generated [B, max_new_tokens].
    """
    B, Sp = prompts.shape
    max_len = Sp + max_new_tokens
    cache = lm.init_cache(cfg, B, max_len, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, moe_impl=moe_impl))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh, moe_impl=moe_impl))
    logits, cache = prefill(params, buffers, {"tokens": prompts}, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    outs = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = decode(params, buffers, nxt[:, None], cache)
        outs.append(nxt)
    from repro.core.cache import measured_cache_bytes
    stats = ServeStats(prefill_tokens=B * Sp, decoded_tokens=B * max_new_tokens,
                       cache_bytes=measured_cache_bytes(cache, B, max_len)["attn_bytes"])
    return np.stack([np.asarray(o) for o in outs], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching over the paged pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler steps (the
    simulated clock) — the Poisson driver maps wall arrival times onto it.

    Sampling is per request: ``temperature <= 0`` is greedy argmax; otherwise
    nucleus sampling from the smallest token set whose probability mass
    reaches ``top_p``, driven by a PRNG keyed on ``seed`` and folded with the
    token index — the same (seed, prompt) always yields the same tokens.
    """
    uid: int
    prompt: np.ndarray                    # [Sp] int32
    max_new_tokens: int
    arrival: float = 0.0
    temperature: float = 0.0              # 0 → greedy
    top_p: float = 1.0                    # nucleus mass (1 → full softmax)
    seed: int = 0                         # per-request PRNG seed
    # filled in by the scheduler:
    generated: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0                  # prefill-source tokens already cached
    prefill_src: Optional[np.ndarray] = None   # recompute source (None → prompt)
    swapped: Optional[Any] = None         # cache.SwappedSeq awaiting swap-in
    preempted_at: List[int] = dataclasses.field(default_factory=list)
    #   ^ len(generated) at each preemption (0 = preempted mid-prefill)
    submit_wall: float = 0.0
    first_token_wall: float = 0.0
    first_token_step: int = -1
    finish_step: int = -1
    finish_reason: str = ""               # "eos" | "budget"

    def prefill_source(self) -> np.ndarray:
        """Tokens that must be cached before decode (re)starts: the prompt,
        or — after a recompute preemption — prompt + generated prefix."""
        return self.prompt if self.prefill_src is None else self.prefill_src


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 4                    # concurrent sequences per decode step
    block_size: int = 16                  # tokens per pool block
    num_blocks: int = 128                 # pool capacity
    max_new_tokens: int = 64              # hard per-request generation cap
    max_len: int = 256                    # per-sequence token cap (table width)
    eos_id: Optional[int] = None
    prefill_bucket: int = 16              # prompts pad up to a multiple of this
    prefill_chunk_tokens: int = 0         # per-lane per-step chunk size
                                          # (0 → whole prompt at admission)
    prefill_batch_lanes: int = 0          # mid-prefill lanes packed per chunked
                                          # forward (0 → max_slots; 1 → PR-3
                                          # one-request-per-chunk behaviour)
    admission: str = "preempt"            # "preempt" | "watermark" (legacy)
    eviction: str = "recompute"           # "recompute" | "swap" (host swap-out)
    use_kernel: bool = True               # Pallas paged kernel on TPU
    cache_dtype: Any = jnp.float32

    @property
    def max_blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)

    @property
    def chunk_lanes(self) -> int:
        return self.prefill_batch_lanes or self.max_slots


def sample_tokens(logits, temps, top_ps, seeds, counts):
    """Batched per-request sampling for one decode step.

    logits [B,V] fp32-castable, temps/top_ps [B] fp32, seeds/counts [B] int32.
    Lane ``i`` draws from PRNG ``fold_in(PRNGKey(seeds[i]), counts[i])`` — the
    count is the request's token index, so replaying a request with the same
    seed reproduces its tokens regardless of which slot/step served it.
    ``temps[i] <= 0`` reduces exactly to greedy argmax.  → [B] int32.
    """

    def one(lg, temp, top_p, seed, count):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        greedy = jnp.argmax(lg).astype(jnp.int32)
        scaled = lg.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        order = jnp.argsort(-scaled)                # descending
        sl = scaled[order]
        probs = jax.nn.softmax(sl)
        # nucleus: drop tokens whose preceding cumulative mass already covers
        # top_p (the smallest covering set always keeps its first member)
        cut = (jnp.cumsum(probs) - probs) >= top_p
        sl = jnp.where(cut, -jnp.inf, sl)
        tok = order[jax.random.categorical(key, sl)].astype(jnp.int32)
        return jnp.where(temp <= 0.0, greedy, tok)

    return jax.vmap(one)(logits, temps, top_ps, seeds, counts)


def ttft_by_prompt_bucket(finished: List[Request],
                          edges: Tuple[int, ...] = (16, 64)) -> Dict[str, float]:
    """Mean TTFT (scheduler steps from arrival to first token) per prompt-
    length bucket — the quantity chunked prefill improves for *short* prompts
    that would otherwise queue behind long ones.  ``edges`` split lengths into
    len(edges)+1 buckets: <=16, 17..64, >64 by default."""
    out: Dict[str, float] = {}
    lo = 0
    for hi in tuple(edges) + (None,):
        label = (f"{lo + 1}-{hi}" if hi is not None else f">{lo}")
        ttfts = [r.first_token_step - r.arrival for r in finished
                 if lo < len(r.prompt) and (hi is None or len(r.prompt) <= hi)]
        if ttfts:
            out[label] = float(np.mean(ttfts))
        lo = hi if hi is not None else lo
    return out


@dataclasses.dataclass
class ServeReport:
    """End-of-run scheduler metrics (docs/serving.md explains how to read
    them).  TTFT = arrival → first token; ``_steps`` is in simulated
    scheduler steps, ``_wall`` in wall milliseconds."""
    completed: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0               # prefill forward calls issued
    decoded_tokens: int = 0
    wall_s: float = 0.0
    tok_per_s: float = 0.0
    ttft_steps_mean: float = 0.0
    ttft_steps_by_bucket: Dict[str, float] = dataclasses.field(default_factory=dict)
    ttft_wall_p50_ms: float = 0.0
    ttft_wall_p95_ms: float = 0.0
    step_ms_p50: float = 0.0
    step_ms_p95: float = 0.0
    peak_slots: int = 0
    pool_high_water_blocks: int = 0
    pool_block_size: int = 0
    naive_blocks: int = 0                 # Σ per-request worst-case blocks
    block_reuse_ratio: float = 0.0        # naive / high-water (>1 ⇒ paging won)
    admission: str = "preempt"            # policy the run used
    preemptions: int = 0                  # evictions forced by OutOfBlocks
    preempted_requests: int = 0           # distinct requests evicted ≥ once
    swap_outs: int = 0                    # preemptions served by host swap
    swap_ins: int = 0                     # swapped prefixes restored
    swapped_bytes: int = 0                # host↔device eviction traffic (out)
    mean_occupancy: float = 0.0           # mean fraction of pool blocks in use
    mean_prefill_batch: float = 0.0       # mean lanes per chunked-prefill call

    def summary(self) -> str:
        bucket = "".join(f" ttft[{k}]={v:.1f}" for k, v in
                         self.ttft_steps_by_bucket.items())
        return (f"completed={self.completed} steps={self.decode_steps} "
                f"decoded={self.decoded_tokens} tok/s={self.tok_per_s:.1f} "
                f"ttft_steps={self.ttft_steps_mean:.1f}{bucket} "
                f"ttft_ms p50/p95={self.ttft_wall_p50_ms:.0f}/{self.ttft_wall_p95_ms:.0f} "
                f"step_ms p50/p95={self.step_ms_p50:.1f}/{self.step_ms_p95:.1f} "
                f"peak_slots={self.peak_slots} "
                f"blocks high-water/naive={self.pool_high_water_blocks}/"
                f"{self.naive_blocks} reuse×{self.block_reuse_ratio:.2f} "
                f"occ={self.mean_occupancy:.2f} [{self.admission}] "
                f"preempt={self.preemptions}"
                f"(swap {self.swap_outs}/{self.swap_ins}) "
                f"prefill_batch={self.mean_prefill_batch:.1f}")


class Scheduler:
    """Continuous-batching serving loop over the paged compressed cache."""

    def __init__(self, params, buffers, cfg: ModelConfig,
                 scfg: SchedulerConfig, mesh=None, moe_impl: str = "ragged"):
        assert cfg.elitekv.enabled, "paged serving requires an EliteKV config"
        assert scfg.eviction in ("recompute", "swap"), scfg.eviction
        self.params, self.buffers, self.cfg, self.scfg = params, buffers, cfg, scfg
        self.pool = PagedKVPool(cfg, scfg.num_blocks, scfg.block_size,
                                dtype=scfg.cache_dtype)
        self.bm = BlockManager(self.pool, policy=scfg.admission)
        self.slots: List[Optional[Request]] = [None] * scfg.max_slots
        self.waiting: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self.t = 0                          # simulated clock (decode steps)
        self._step_wall_ms: List[float] = []
        self._occupancy: List[float] = []   # pool fill fraction per step
        self.peak_slots = 0
        self.naive_blocks = 0
        self.prefill_chunks = 0             # prefill forward calls issued
        self._prefill_lanes_total = 0       # Σ live lanes over those calls

        def _prefill(params, buffers, tokens, pages, slot_mapping):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping, moe_impl=moe_impl,
                                          mesh=mesh)

        def _prefill_batch(params, buffers, tokens, pages, slot_mapping,
                           chunk_starts, block_tables, prefix_lens):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping,
                                          chunk_start=chunk_starts,
                                          block_tables=block_tables,
                                          prefix_lens=prefix_lens,
                                          block_size=scfg.block_size,
                                          moe_impl=moe_impl, mesh=mesh)

        def _decode(params, buffers, tokens, pages, slot_mapping,
                    block_tables, lengths):
            return lm.apply_decode_paged(params, buffers, cfg,
                                         {"tokens": tokens}, pages,
                                         slot_mapping, block_tables, lengths,
                                         block_size=scfg.block_size,
                                         use_kernel=scfg.use_kernel,
                                         moe_impl=moe_impl, mesh=mesh)

        # donate the pages so XLA updates the pool in place rather than
        # copying every block each step (donation is unsupported + noisy on CPU)
        donate = () if jax.default_backend() == "cpu" else (3,)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._prefill_batch = jax.jit(_prefill_batch, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._sample = jax.jit(sample_tokens)

    # -- request intake -----------------------------------------------------
    def submit(self, req: Request) -> None:
        req.max_new_tokens = min(req.max_new_tokens, self.scfg.max_new_tokens)
        assert len(req.prompt) + req.max_new_tokens <= self.scfg.max_len, \
            (len(req.prompt), req.max_new_tokens, self.scfg.max_len)
        if self._worst_case_blocks(req) > self.scfg.num_blocks:
            raise OutOfBlocks(
                f"request {req.uid} needs {self._worst_case_blocks(req)} blocks "
                f"worst-case but the pool only has {self.scfg.num_blocks} — "
                f"it could never be admitted")
        req.submit_wall = time.perf_counter()
        self.waiting.append(req)
        self.naive_blocks += self._worst_case_blocks(req)

    def _worst_case_blocks(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens) // self.scfg.block_size)

    def _first_alloc_tokens(self, req: Request) -> int:
        """Pool tokens the request needs *immediately* at admission: the
        swapped-out prefix being restored, the first prefill chunk, or (one-
        shot mode) the whole prefill source."""
        if req.swapped is not None:
            return req.swapped.length
        src = len(req.prefill_source())
        chunk = self.scfg.prefill_chunk_tokens
        return min(chunk, src) if chunk > 0 else src

    # -- admission ----------------------------------------------------------
    def _try_admit(self) -> int:
        admitted = 0
        while self.waiting and self.waiting[0].arrival <= self.t:
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                break
            req = self.waiting[0]
            if not self.bm.can_admit(self._first_alloc_tokens(req),
                                     self._worst_case_blocks(req)):
                break                       # head-of-line waits for blocks
            self.waiting.popleft()
            self._admit(slot, req)
            admitted += 1
        return admitted

    def _admit(self, slot: int, req: Request) -> None:
        """Claim a slot (restoring a swapped-out prefix if there is one).
        Block allocation otherwise happens on demand, chunk by chunk, in
        ``_prefill_work`` — and prefill itself is interleaved with decode."""
        if req.swapped is not None:
            self.bm.swap_in(req.uid, req.swapped)
            req.swapped = None
        self.bm.register(req.uid, self._worst_case_blocks(req))
        self.slots[slot] = req

    # -- preemption ---------------------------------------------------------
    def _decode_ready(self, req: Request) -> bool:
        """Prefill source fully cached and the next input token sampled."""
        return bool(req.generated) and \
            req.prefill_pos >= len(req.prefill_source())

    def _youngest_slot(self) -> Optional[int]:
        occ = [(s.arrival, s.uid, i)
               for i, s in enumerate(self.slots) if s is not None]
        return max(occ)[2] if occ else None

    def _preempt(self, slot: int) -> None:
        """Evict the resident in ``slot`` and requeue it at the head of the
        waiting line.  ``eviction="recompute"`` frees its blocks and arms a
        recompute-prefill over prompt + generated-so-far (whose final logits
        re-produce exactly the token the interrupted decode step would have);
        ``eviction="swap"`` copies the cached prefix to host memory instead,
        restored block-exactly at re-admission."""
        req = self.slots[slot]
        req.preempted_at.append(len(req.generated))
        if self.scfg.eviction == "swap":
            # cached tokens from *request* state: prompt + generated minus the
            # not-yet-written last token (decode-ready), or the prefill cursor
            if self._decode_ready(req):
                cached = len(req.prompt) + len(req.generated) - 1
                req.prefill_src = np.concatenate(
                    [req.prompt,
                     np.asarray(req.generated[:-1], np.int32)])
                req.prefill_pos = cached
            else:
                cached = req.prefill_pos
            req.swapped = self.bm.preempt_swap_out(req.uid, cached)
        else:
            if req.generated:
                req.prefill_src = np.concatenate(
                    [req.prompt, np.asarray(req.generated, np.int32)])
            req.prefill_pos = 0
            self.bm.preempt_recompute(req.uid)
        self.slots[slot] = None
        self.waiting.appendleft(req)

    def _grow_or_preempt(self, req: Request, length: int) -> bool:
        """Grow ``req``'s chain to ``length`` tokens, preempting the youngest
        resident until the allocation fits.  Returns False iff ``req`` itself
        was the youngest and got evicted (caller drops it this step).
        Terminates: every retry removes one resident, and a lone resident's
        worst case fits the pool (enforced at ``submit``)."""
        while True:
            try:
                self.bm.grow(req.uid, length)
                return True
            except OutOfBlocks:
                slot = self._youngest_slot()
                if slot is None:
                    raise
                victim = self.slots[slot]
                self._preempt(slot)
                if victim is req:
                    return False

    # -- chunked / batched prefill ------------------------------------------
    def _sample_prefill_token(self, req: Request, last_row) -> None:
        """Sample the token that follows a completed (re)prefill from its
        final logits row.  The PRNG count is ``len(generated)``: 0 for a
        fresh prompt (the request's first token), ``k`` after a recompute —
        re-drawing exactly the token the interrupted decode step would have
        produced, so preemption never changes the stream."""
        if req.temperature > 0:
            tok = int(np.asarray(self._sample(
                last_row[None],
                jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_p], jnp.float32),
                jnp.asarray([req.seed], jnp.int32),
                jnp.asarray([len(req.generated)], jnp.int32)))[0])
        else:
            tok = int(jnp.argmax(last_row))
        req.generated.append(tok)
        if req.first_token_step < 0:        # TTFT survives preemption
            req.first_token_wall = time.perf_counter()
            req.first_token_step = self.t

    def _run_oneshot(self, slot: int, req: Request) -> None:
        """Whole-source causal prefill in one call, padded to the bucket."""
        src = req.prefill_source()
        sp = len(src)
        if not self._grow_or_preempt(req, sp):
            return                          # req evicted itself — retry later
        pad = -(-sp // self.scfg.prefill_bucket) * self.scfg.prefill_bucket
        tokens = np.zeros((1, pad), np.int32)
        tokens[0, :sp] = src
        sm = self.pool.prefill_slot_mapping(req.uid, 0, sp, pad)[None]
        logits, self.pool.pages = self._prefill(
            self.params, self.buffers, jnp.asarray(tokens),
            self.pool.pages, jnp.asarray(sm))
        req.prefill_pos = sp
        self.prefill_chunks += 1
        self._prefill_lanes_total += 1
        self._sample_prefill_token(req, logits[0, sp - 1])
        self._maybe_finish(slot, req.generated[-1])

    def _prefill_work(self) -> None:
        """Advance mid-prefill residents.  One-shot mode (``chunk == 0``):
        each pending prompt prefills whole, FCFS.  Chunked mode: pack the
        next ``prefill_chunk_tokens``-token chunk of up to ``chunk_lanes``
        lanes (FCFS by arrival) into ONE fixed-shape forward — per-lane
        ``chunk_start``/``prefix_lens`` vectors give every lane its own
        offset causal mask against its own paged prefix."""
        scfg = self.scfg
        chunk = scfg.prefill_chunk_tokens
        if chunk <= 0:
            while True:
                cand = [(s.arrival, s.uid, i)
                        for i, s in enumerate(self.slots)
                        if s is not None
                        and s.prefill_pos < len(s.prefill_source())]
                if not cand:
                    return
                _, _, slot = min(cand)
                self._run_oneshot(slot, self.slots[slot])
        # chunked: FCFS-select lanes, growing each chain for its chunk
        # (growth may preempt residents — including already-selected lanes)
        cand = sorted((s.arrival, s.uid, i)
                      for i, s in enumerate(self.slots)
                      if s is not None
                      and s.prefill_pos < len(s.prefill_source()))
        selected: List[Tuple[int, Request, int, int]] = []
        for _, _, slot in cand:
            if len(selected) >= scfg.chunk_lanes:
                break
            req = self.slots[slot]
            if req is None:                 # evicted by an earlier growth
                continue
            n = min(chunk, len(req.prefill_source()) - req.prefill_pos)
            if self._grow_or_preempt(req, req.prefill_pos + n):
                selected.append((slot, req, req.prefill_pos, n))
        selected = [(s, r, st, n) for s, r, st, n in selected
                    if self.slots[s] is r]  # drop lanes evicted after selection
        if not selected:
            return
        lanes = scfg.chunk_lanes
        tokens = np.zeros((lanes, chunk), np.int32)
        sms = np.full((lanes, chunk), self.pool.oob_slot, np.int32)
        starts = np.zeros((lanes,), np.int32)
        seq_ids: List[Optional[int]] = [None] * lanes
        for lane, (slot, req, start, n) in enumerate(selected):
            tokens[lane, :n] = req.prefill_source()[start:start + n]
            sms[lane] = self.pool.prefill_slot_mapping(req.uid, start, n, chunk)
            starts[lane] = start            # chunk offset == cached prefix len
            seq_ids[lane] = req.uid
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)
        logits, self.pool.pages = self._prefill_batch(
            self.params, self.buffers, jnp.asarray(tokens), self.pool.pages,
            jnp.asarray(sms), jnp.asarray(starts), jnp.asarray(bt),
            jnp.asarray(starts))
        self.prefill_chunks += 1
        self._prefill_lanes_total += len(selected)
        for lane, (slot, req, start, n) in enumerate(selected):
            req.prefill_pos = start + n
            if req.prefill_pos >= len(req.prefill_source()):
                self._sample_prefill_token(req, logits[lane, n - 1])
                self._maybe_finish(slot, req.generated[-1])

    # -- retirement ---------------------------------------------------------
    def _maybe_finish(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        if self.scfg.eos_id is not None and token == self.scfg.eos_id:
            req.finish_reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            req.finish_reason = "budget"
        else:
            return
        req.finish_step = self.t
        self.bm.release(req.uid)            # blocks recycle immediately
        self.finished.append(req)
        self.slots[slot] = None

    # -- one scheduler iteration -------------------------------------------
    def step(self) -> bool:
        """Admit + chunk-prefill + decode once.  Returns False when drained."""
        self._try_admit()
        self._prefill_work()
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        self.peak_slots = max(self.peak_slots, len(occupied))
        # decode lanes: slots whose prefill source is fully cached.  Grow
        # each chain one token, oldest lane first — growth may preempt the
        # youngest residents (who then sit out this step in the queue).
        grown: Dict[int, int] = {}          # slot → position of the new token
        order = sorted((self.slots[i].arrival, self.slots[i].uid, i)
                       for i in occupied if self._decode_ready(self.slots[i]))
        for _, _, i in order:
            req = self.slots[i]
            if req is None:
                continue                    # evicted by an older lane's growth
            cur = self.pool.length(req.uid)
            if self._grow_or_preempt(req, cur + 1):
                grown[i] = cur
        active = [i for i in grown if self.slots[i] is not None]
        self._occupancy.append(
            self.pool.allocator.num_used / self.pool.num_blocks)
        if not active:
            if all(s is None for s in self.slots) and not self.waiting:
                return False
            self.t += 1                     # waiting on arrivals or prefill
            return True

        scfg = self.scfg
        B = scfg.max_slots
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ps = np.ones((B,), np.float32)
        seeds = np.zeros((B,), np.int32)
        counts = np.zeros((B,), np.int32)
        seq_ids: List[Optional[int]] = [None] * B
        positions = [0] * B
        for i in active:
            req = self.slots[i]
            cur = grown[i]                  # chain already grown above
            tokens[i, 0] = req.generated[-1]
            lengths[i] = cur + 1
            seq_ids[i] = req.uid
            positions[i] = cur
            temps[i] = req.temperature
            top_ps[i] = req.top_p
            seeds[i] = req.seed
            counts[i] = len(req.generated)  # token index within the request
        sm = self.pool.slot_mapping(seq_ids, positions)
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)

        t0 = time.perf_counter()
        logits, self.pool.pages = self._decode(self.params, self.buffers,
                                               jnp.asarray(tokens),
                                               self.pool.pages,
                                               jnp.asarray(sm), jnp.asarray(bt),
                                               jnp.asarray(lengths))
        if np.any(temps > 0):
            nxt = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(temps),
                                          jnp.asarray(top_ps),
                                          jnp.asarray(seeds),
                                          jnp.asarray(counts)))
        else:                               # all-greedy step: skip the
            nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))  # sampler
        self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)
        self.t += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self._maybe_finish(i, tok)
        return bool(self.waiting) or any(s is not None for s in self.slots)

    # -- drive to completion ------------------------------------------------
    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> ServeReport:
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"scheduler did not drain in {max_steps} steps")
        return self.report(time.perf_counter() - t0)

    def report(self, wall_s: float) -> ServeReport:
        fin = self.finished
        decoded = sum(len(r.generated) for r in fin)
        prefill_toks = sum(len(r.prompt) for r in fin)
        ttft_steps = [r.first_token_step - r.arrival for r in fin]
        ttft_ms = [(r.first_token_wall - r.submit_wall) * 1e3 for r in fin]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        hw = self.pool.allocator.high_water
        return ServeReport(
            completed=len(fin), decode_steps=len(self._step_wall_ms),
            prefill_tokens=prefill_toks, prefill_chunks=self.prefill_chunks,
            decoded_tokens=decoded,
            wall_s=wall_s, tok_per_s=decoded / max(wall_s, 1e-9),
            ttft_steps_mean=float(np.mean(ttft_steps)) if ttft_steps else 0.0,
            ttft_steps_by_bucket=ttft_by_prompt_bucket(fin),
            ttft_wall_p50_ms=pct(ttft_ms, 50), ttft_wall_p95_ms=pct(ttft_ms, 95),
            step_ms_p50=pct(self._step_wall_ms, 50),
            step_ms_p95=pct(self._step_wall_ms, 95),
            peak_slots=self.peak_slots, pool_high_water_blocks=hw,
            pool_block_size=self.scfg.block_size,
            naive_blocks=self.naive_blocks,
            block_reuse_ratio=self.naive_blocks / max(hw, 1),
            admission=self.scfg.admission,
            preemptions=self.bm.preemptions,
            preempted_requests=sum(1 for r in fin if r.preempted_at),
            swap_outs=self.bm.swap_outs, swap_ins=self.bm.swap_ins,
            swapped_bytes=self.bm.swapped_bytes,
            mean_occupancy=(float(np.mean(self._occupancy))
                            if self._occupancy else 0.0),
            mean_prefill_batch=(self._prefill_lanes_total
                                / max(self.prefill_chunks, 1)))


def generate_paged(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
                   max_new_tokens: int, scfg: Optional[SchedulerConfig] = None
                   ) -> Tuple[np.ndarray, ServeReport]:
    """Paged-pool twin of ``generate`` (same greedy semantics, same output
    shape) — the parity surface for scheduler tests."""
    B, Sp = prompts.shape
    scfg = scfg or SchedulerConfig(
        max_slots=B, max_new_tokens=max_new_tokens,
        max_len=Sp + max_new_tokens + 1,
        num_blocks=2 * B * (-(-(Sp + max_new_tokens) // 16)), block_size=16)
    sched = Scheduler(params, buffers, cfg, scfg)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i]),
                    max_new_tokens=max_new_tokens) for i in range(B)]
    report = sched.run(reqs)
    out = np.zeros((B, max_new_tokens), np.int32)
    for r in sched.finished:
        out[r.uid, :len(r.generated)] = r.generated
    return out, report
