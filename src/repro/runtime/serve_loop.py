"""Serving runtime over the compressed EliteKV cache.

Two tiers:

* ``generate`` — lockstep batched greedy decoding with a contiguous cache
  (examples / parity oracle).
* ``Scheduler`` — continuous batching over the block-paged pool
  (``core.cache.PagedKVPool``): requests queue with arrival times, get
  admitted into free *slots* mid-flight, are prefilled while resident slots
  keep decoding, and retire on EOS or token budget — their blocks recycle
  immediately.  Decode runs one jit-compiled step over all ``max_slots``
  lanes regardless of occupancy (idle lanes are masked by length 0), so the
  whole serving run compiles exactly once per prompt-length bucket plus once
  for decode.

Admission reserves *watermark* capacity (worst-case remaining blocks of every
resident sequence) so a decode step can never run out of pool blocks
mid-flight; physical blocks are still allocated on demand, one at a time, so
peak usage stays far below the sum of per-request worst cases whenever
arrivals stagger or sequences stop early.  Preemption/swap-out is a ROADMAP
item.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import OutOfBlocks, PagedKVPool
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, mesh=None, constrain=None,
                      moe_impl: str = "ragged", data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def prefill_step(params, buffers, batch, cache):
        return lm.apply_prefill(params, buffers, cfg, batch, cache,
                                moe_impl=moe_impl, mesh=mesh,
                                constrain=constrain, data_axes=data_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, constrain=None,
                     moe_impl: str = "ragged", greedy: bool = True,
                     data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def decode_step(params, buffers, tokens, cache):
        batch = ({"tokens": tokens} if cfg.frontend != "audio"
                 else {"frames": tokens})
        logits, cache = lm.apply_decode(params, buffers, cfg, batch, cache,
                                        moe_impl=moe_impl, mesh=mesh,
                                        constrain=constrain, data_axes=data_axes)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_bytes: int = 0


def generate(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
             max_new_tokens: int, mesh=None, moe_impl: str = "ragged",
             cache_dtype=jnp.float32) -> Tuple[np.ndarray, ServeStats]:
    """Greedy generation for a batch of fixed-length prompts (examples/tests).

    prompts: [B, S_prompt] int32 → generated [B, max_new_tokens].
    """
    B, Sp = prompts.shape
    max_len = Sp + max_new_tokens
    cache = lm.init_cache(cfg, B, max_len, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, moe_impl=moe_impl))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh, moe_impl=moe_impl))
    logits, cache = prefill(params, buffers, {"tokens": prompts}, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    outs = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = decode(params, buffers, nxt[:, None], cache)
        outs.append(nxt)
    from repro.core.cache import measured_cache_bytes
    stats = ServeStats(prefill_tokens=B * Sp, decoded_tokens=B * max_new_tokens,
                       cache_bytes=measured_cache_bytes(cache, B, max_len)["attn_bytes"])
    return np.stack([np.asarray(o) for o in outs], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching over the paged pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler steps (the
    simulated clock) — the Poisson driver maps wall arrival times onto it."""
    uid: int
    prompt: np.ndarray                    # [Sp] int32
    max_new_tokens: int
    arrival: float = 0.0
    # filled in by the scheduler:
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_wall: float = 0.0
    first_token_wall: float = 0.0
    first_token_step: int = -1
    finish_step: int = -1
    finish_reason: str = ""               # "eos" | "budget"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 4                    # concurrent sequences per decode step
    block_size: int = 16                  # tokens per pool block
    num_blocks: int = 128                 # pool capacity
    max_new_tokens: int = 64              # hard per-request generation cap
    max_len: int = 256                    # per-sequence token cap (table width)
    eos_id: Optional[int] = None
    prefill_bucket: int = 16              # prompts pad up to a multiple of this
    use_kernel: bool = True               # Pallas paged kernel on TPU
    cache_dtype: Any = jnp.float32

    @property
    def max_blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)


@dataclasses.dataclass
class ServeReport:
    completed: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    wall_s: float = 0.0
    tok_per_s: float = 0.0
    ttft_steps_mean: float = 0.0
    ttft_wall_p50_ms: float = 0.0
    ttft_wall_p95_ms: float = 0.0
    step_ms_p50: float = 0.0
    step_ms_p95: float = 0.0
    peak_slots: int = 0
    pool_high_water_blocks: int = 0
    pool_block_size: int = 0
    naive_blocks: int = 0                 # Σ per-request worst-case blocks
    block_reuse_ratio: float = 0.0        # naive / high-water (>1 ⇒ paging won)

    def summary(self) -> str:
        return (f"completed={self.completed} steps={self.decode_steps} "
                f"decoded={self.decoded_tokens} tok/s={self.tok_per_s:.1f} "
                f"ttft_steps={self.ttft_steps_mean:.1f} "
                f"ttft_ms p50/p95={self.ttft_wall_p50_ms:.0f}/{self.ttft_wall_p95_ms:.0f} "
                f"step_ms p50/p95={self.step_ms_p50:.1f}/{self.step_ms_p95:.1f} "
                f"peak_slots={self.peak_slots} "
                f"blocks high-water/naive={self.pool_high_water_blocks}/"
                f"{self.naive_blocks} reuse×{self.block_reuse_ratio:.2f}")


class Scheduler:
    """Continuous-batching serving loop over the paged compressed cache."""

    def __init__(self, params, buffers, cfg: ModelConfig,
                 scfg: SchedulerConfig, mesh=None, moe_impl: str = "ragged"):
        assert cfg.elitekv.enabled, "paged serving requires an EliteKV config"
        self.params, self.buffers, self.cfg, self.scfg = params, buffers, cfg, scfg
        self.pool = PagedKVPool(cfg, scfg.num_blocks, scfg.block_size,
                                dtype=scfg.cache_dtype)
        self.slots: List[Optional[Request]] = [None] * scfg.max_slots
        self.waiting: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self.t = 0                          # simulated clock (decode steps)
        self._reserved_blocks = 0           # watermark: worst-case growth of residents
        self._step_wall_ms: List[float] = []
        self.peak_slots = 0
        self.naive_blocks = 0

        def _prefill(params, buffers, tokens, pages, slot_mapping):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping, moe_impl=moe_impl,
                                          mesh=mesh)

        def _decode(params, buffers, tokens, pages, slot_mapping,
                    block_tables, lengths):
            return lm.apply_decode_paged(params, buffers, cfg,
                                         {"tokens": tokens}, pages,
                                         slot_mapping, block_tables, lengths,
                                         block_size=scfg.block_size,
                                         use_kernel=scfg.use_kernel,
                                         moe_impl=moe_impl, mesh=mesh)

        # donate the pages so XLA updates the pool in place rather than
        # copying every block each step (donation is unsupported + noisy on CPU)
        donate = () if jax.default_backend() == "cpu" else (3,)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)

    # -- request intake -----------------------------------------------------
    def submit(self, req: Request) -> None:
        req.max_new_tokens = min(req.max_new_tokens, self.scfg.max_new_tokens)
        assert len(req.prompt) + req.max_new_tokens <= self.scfg.max_len, \
            (len(req.prompt), req.max_new_tokens, self.scfg.max_len)
        if self._worst_case_blocks(req) > self.scfg.num_blocks:
            raise OutOfBlocks(
                f"request {req.uid} needs {self._worst_case_blocks(req)} blocks "
                f"worst-case but the pool only has {self.scfg.num_blocks} — "
                f"it could never be admitted")
        req.submit_wall = time.perf_counter()
        self.waiting.append(req)
        self.naive_blocks += self._worst_case_blocks(req)

    def _worst_case_blocks(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens) // self.scfg.block_size)

    def _recompute_reserved(self) -> None:
        """Watermark: worst-case blocks still owed to resident sequences.
        Admission against ``num_free - reserved`` guarantees decode can always
        grow every resident by its full budget — no mid-flight OutOfBlocks."""
        self._reserved_blocks = sum(
            max(0, self._worst_case_blocks(s) - len(self.pool.block_table(s.uid)))
            for s in self.slots if s is not None)

    # -- admission ----------------------------------------------------------
    def _try_admit(self) -> int:
        admitted = 0
        self._recompute_reserved()
        while self.waiting and self.waiting[0].arrival <= self.t:
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                break
            req = self.waiting[0]
            need = self._worst_case_blocks(req)
            if self.pool.allocator.num_free - self._reserved_blocks < need:
                break                       # pool watermark exhausted — wait
            self.waiting.popleft()
            self._admit(slot, req)
            self._recompute_reserved()
            admitted += 1
        return admitted

    def _admit(self, slot: int, req: Request) -> None:
        scfg = self.scfg
        sp = len(req.prompt)
        pad = -(-sp // scfg.prefill_bucket) * scfg.prefill_bucket
        self.pool.ensure_capacity(req.uid, sp)
        tokens = np.zeros((1, pad), np.int32)
        tokens[0, :sp] = req.prompt
        sm = self.pool.prefill_slot_mapping(req.uid, 0, sp, pad)[None]
        logits, self.pool.pages = self._prefill(self.params, self.buffers,
                                                jnp.asarray(tokens),
                                                self.pool.pages,
                                                jnp.asarray(sm))
        first = int(jnp.argmax(logits[0, sp - 1]))
        req.generated.append(first)
        req.first_token_wall = time.perf_counter()
        req.first_token_step = self.t
        self.slots[slot] = req
        self._maybe_finish(slot, first)

    # -- retirement ---------------------------------------------------------
    def _maybe_finish(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        if self.scfg.eos_id is not None and token == self.scfg.eos_id:
            req.finish_reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            req.finish_reason = "budget"
        else:
            return
        req.finish_step = self.t
        self.pool.free_seq(req.uid)         # blocks recycle immediately
        self.finished.append(req)
        self.slots[slot] = None

    # -- one scheduler iteration -------------------------------------------
    def step(self) -> bool:
        """Admit + decode once.  Returns False when fully drained."""
        self._try_admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        self.peak_slots = max(self.peak_slots, len(active))
        if not active:
            if not self.waiting:
                return False
            self.t += 1                     # idle tick: wait for next arrival
            return True

        scfg = self.scfg
        B = scfg.max_slots
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        seq_ids: List[Optional[int]] = [None] * B
        positions = [0] * B
        for i in active:
            req = self.slots[i]
            cur = self.pool.length(req.uid)
            self.pool.ensure_capacity(req.uid, cur + 1)   # may grow one block
            tokens[i, 0] = req.generated[-1]
            lengths[i] = cur + 1
            seq_ids[i] = req.uid
            positions[i] = cur
        sm = self.pool.slot_mapping(seq_ids, positions)
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)

        t0 = time.perf_counter()
        logits, self.pool.pages = self._decode(self.params, self.buffers,
                                               jnp.asarray(tokens),
                                               self.pool.pages,
                                               jnp.asarray(sm), jnp.asarray(bt),
                                               jnp.asarray(lengths))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)
        self.t += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self._maybe_finish(i, tok)
        return bool(self.waiting) or any(s is not None for s in self.slots)

    # -- drive to completion ------------------------------------------------
    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> ServeReport:
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"scheduler did not drain in {max_steps} steps")
        return self.report(time.perf_counter() - t0)

    def report(self, wall_s: float) -> ServeReport:
        fin = self.finished
        decoded = sum(len(r.generated) for r in fin)
        prefill_toks = sum(len(r.prompt) for r in fin)
        ttft_steps = [r.first_token_step - r.arrival for r in fin]
        ttft_ms = [(r.first_token_wall - r.submit_wall) * 1e3 for r in fin]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        hw = self.pool.allocator.high_water
        return ServeReport(
            completed=len(fin), decode_steps=len(self._step_wall_ms),
            prefill_tokens=prefill_toks, decoded_tokens=decoded,
            wall_s=wall_s, tok_per_s=decoded / max(wall_s, 1e-9),
            ttft_steps_mean=float(np.mean(ttft_steps)) if ttft_steps else 0.0,
            ttft_wall_p50_ms=pct(ttft_ms, 50), ttft_wall_p95_ms=pct(ttft_ms, 95),
            step_ms_p50=pct(self._step_wall_ms, 50),
            step_ms_p95=pct(self._step_wall_ms, 95),
            peak_slots=self.peak_slots, pool_high_water_blocks=hw,
            pool_block_size=self.scfg.block_size,
            naive_blocks=self.naive_blocks,
            block_reuse_ratio=self.naive_blocks / max(hw, 1))


def generate_paged(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
                   max_new_tokens: int, scfg: Optional[SchedulerConfig] = None
                   ) -> Tuple[np.ndarray, ServeReport]:
    """Paged-pool twin of ``generate`` (same greedy semantics, same output
    shape) — the parity surface for scheduler tests."""
    B, Sp = prompts.shape
    scfg = scfg or SchedulerConfig(
        max_slots=B, max_new_tokens=max_new_tokens,
        max_len=Sp + max_new_tokens + 1,
        num_blocks=2 * B * (-(-(Sp + max_new_tokens) // 16)), block_size=16)
    sched = Scheduler(params, buffers, cfg, scfg)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i]),
                    max_new_tokens=max_new_tokens) for i in range(B)]
    report = sched.run(reqs)
    out = np.zeros((B, max_new_tokens), np.int32)
    for r in sched.finished:
        out[r.uid, :len(r.generated)] = r.generated
    return out, report
