"""Serving runtime: prefill + decode step factories and a batched request
loop over the compressed EliteKV cache (continuous-batching style slots).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, mesh=None, constrain=None,
                      moe_impl: str = "ragged", data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def prefill_step(params, buffers, batch, cache):
        return lm.apply_prefill(params, buffers, cfg, batch, cache,
                                moe_impl=moe_impl, mesh=mesh,
                                constrain=constrain, data_axes=data_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, constrain=None,
                     moe_impl: str = "ragged", greedy: bool = True,
                     data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def decode_step(params, buffers, tokens, cache):
        batch = ({"tokens": tokens} if cfg.frontend != "audio"
                 else {"frames": tokens})
        logits, cache = lm.apply_decode(params, buffers, cfg, batch, cache,
                                        moe_impl=moe_impl, mesh=mesh,
                                        constrain=constrain, data_axes=data_axes)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_bytes: int = 0


def generate(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
             max_new_tokens: int, mesh=None, moe_impl: str = "ragged",
             cache_dtype=jnp.float32) -> Tuple[np.ndarray, ServeStats]:
    """Greedy generation for a batch of fixed-length prompts (examples/tests).

    prompts: [B, S_prompt] int32 → generated [B, max_new_tokens].
    """
    B, Sp = prompts.shape
    max_len = Sp + max_new_tokens
    cache = lm.init_cache(cfg, B, max_len, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, moe_impl=moe_impl))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh, moe_impl=moe_impl))
    logits, cache = prefill(params, buffers, {"tokens": prompts}, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    outs = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = decode(params, buffers, nxt[:, None], cache)
        outs.append(nxt)
    from repro.core.cache import measured_cache_bytes
    stats = ServeStats(prefill_tokens=B * Sp, decoded_tokens=B * max_new_tokens,
                       cache_bytes=measured_cache_bytes(cache, B, max_len)["attn_bytes"])
    return np.stack([np.asarray(o) for o in outs], axis=1), stats
