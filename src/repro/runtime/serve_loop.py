"""Serving runtime over the compressed EliteKV cache (see docs/serving.md).

Two tiers:

* ``generate`` — lockstep batched greedy decoding with a contiguous cache
  (examples / parity oracle).
* ``Scheduler`` — continuous batching over the block-paged pool
  (``core.cache.PagedKVPool`` + ``core.cache.BlockManager``): requests queue
  with arrival times, get admitted into free *slots* mid-flight, prefill
  their prompts in fixed-size token **chunks** interleaved with decode steps
  (so a long arriving prompt never stalls resident sequences), and retire on
  EOS or token budget — their blocks recycle immediately.  Each scheduler
  step packs up to ``prefill_batch_lanes`` mid-prefill sequences' chunks
  (``prefill_chunk_tokens`` each) into **one** padded forward — per-lane
  ``chunk_start`` / ``prefix_lens`` vectors let resumed chunks of different
  sequences attend to their own paged prefixes in the same call — then runs
  one decode step over all ``max_slots`` lanes (idle and still-prefilling
  lanes are masked by length 0).  With ``prefill_chunk_tokens=0`` the whole
  prompt is prefilled at admission in one call (PR-2 behaviour).  The run
  compiles once per prompt-length bucket (one-shot), once for the fixed
  batched chunk shape (chunked), plus once for decode.

Decoding samples per request: temperature / nucleus (top-p) with a
per-request PRNG seed, applied batched over all lanes in one jitted call;
``temperature=0`` lanes reduce exactly to greedy argmax.

Speculative decode (``speculate_k > 0``) replaces the one-token decode step
with a draft/verify macro-step: ``k`` cheap decode forwards of a
rank-truncated *draft* model (``models.lm.make_draft_params`` — the top
singular directions of the existing joint low-rank factors, sharing the same
paged latent cache) propose up to ``k`` tokens per resident, then ONE
full-model verify forward (``models.lm.apply_verify_paged``) re-scores all
``k+1`` window positions against the paged prefix.  Acceptance is standard
distribution-preserving rejection sampling against the per-request
temperature/top-p target using the same count-folded PRNG (greedy lanes
accept on exact argmax match), so accepted streams match plain decode in
distribution — and exactly under greedy (or with a full-rank draft), where
the stream is also invariant under preemption.  A *truncated*-draft sampled
stream is path-dependent by construction — which token the accept coin
judges depends on where the macro-step windows fall, so preemption (which
shifts window alignment) can change the realized sample while preserving
its distribution, exactly as in standard speculative sampling.  Rejected
tokens roll the pool chain back via ``BlockManager.truncate``; the decode
hot path advances ``1 + accepted`` tokens per verify forward instead of 1.

Admission (``admission="preempt"``, the default) holds nothing back: a
request is admitted as soon as its next allocation fits, residents grow
blocks on demand, and when the pool runs dry mid-flight the scheduler
**preempts the youngest resident** — frees its blocks and requeues it at the
head of the waiting line for a recompute-prefill of its already-generated
prefix (``eviction="recompute"``), or copies its cached streams to host
memory and restores them block-exactly on re-admission
(``eviction="swap"``).  Token streams are invariant under preemption: a
recomputed prefix reproduces the exact logits the interrupted decode step
would have seen, and the count-folded sampling PRNG re-draws the exact same
token.  ``admission="watermark"`` keeps the legacy reservation policy
(worst-case remaining blocks of every resident held back, so growth can
never fail) for comparison runs — it trades occupancy for never preempting.

Prefix caching (``prefix_cache=True``, docs/serving.md): the pool's blocks
become shareable across requests.  At admission the scheduler probes the
``BlockManager``'s content-addressed prefix cache with the request's prompt
(chained hashes of full token blocks); cached blocks are spliced into the
newcomer's chain and prefill *skips every fully-covered chunk*, resuming
chunked-prefill attention at the first miss.  Freshly prefilled full prompt
blocks are registered after every chunk, so a long shared system prompt
warms the cache for requests arriving mid-prefill.  All writes go through a
copy-on-write barrier (``prepare_write`` inside ``_grow_or_preempt``), so
the token streams are invariant: a cache-on run emits exactly the cache-off
tokens (greedy and sampled, under preemption and speculative decode —
tests/test_prefix_cache.py pins the wall).  Retired prefixes stay retained
in an LRU until the allocator actually needs their blocks.

Observability (docs/observability.md): the scheduler accepts a
``repro.obs.trace.Tracer`` and a ``repro.obs.metrics.MetricsRegistry``.  Every
step is decomposed into host-observable **phases** (``PHASES``) — prefill /
decode / draft / verify forwards, sampling, speculative accept bookkeeping,
swap copies, plus an ``other`` residual — whose wall totals land in
``ServeReport.phase_ms`` and, when a tracer is attached, as spans on the
``scheduler`` timeline track; per-request lifecycle events (submit, admit,
prefill chunks, preempt, retire) land on per-slot tracks and the pool emits
its own alloc/free/swap/truncate events.  Instrumentation is passive: it
reads clocks and appends host-side records, never touching PRNG or
scheduling state, so a traced run emits bit-identical tokens to an untraced
one (tests/test_obs.py pins this).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
from repro.models import lm
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER

#: Host-observable phases of one scheduler step (``ServeReport.phase_ms``
#: keys — all always present, zero-valued when a phase never ran).  ``other``
#: is the per-step residual (admission, growth bookkeeping, host packing),
#: computed so the phase totals sum to ``step_wall_ms_total``.  The jitted
#: forwards are opaque to host timing, so the embedding dispatch is folded
#: into its enclosing forward phase.
PHASES = ("prefill", "decode", "draft", "verify", "sample", "accept",
          "swap", "other")


def make_prefill_step(cfg: ModelConfig, mesh=None, constrain=None,
                      moe_impl: str = "ragged", data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def prefill_step(params, buffers, batch, cache):
        return lm.apply_prefill(params, buffers, cfg, batch, cache,
                                moe_impl=moe_impl, mesh=mesh,
                                constrain=constrain, data_axes=data_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, constrain=None,
                     moe_impl: str = "ragged", greedy: bool = True,
                     data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def decode_step(params, buffers, tokens, cache):
        batch = ({"tokens": tokens} if cfg.frontend != "audio"
                 else {"frames": tokens})
        logits, cache = lm.apply_decode(params, buffers, cfg, batch, cache,
                                        moe_impl=moe_impl, mesh=mesh,
                                        constrain=constrain, data_axes=data_axes)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    """Counters for the lockstep ``generate`` path.

    ``prefill_tokens``  — prompt tokens pushed through the prefill forward
                          (batch × prompt length).
    ``decoded_tokens``  — tokens produced by decode steps (batch × new tokens).
    ``cache_bytes``     — measured bytes of the attention KV cache actually
                          allocated for the run (the paper's headline
                          compression shows up here).
    """
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_bytes: int = 0


def generate(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
             max_new_tokens: int, mesh=None, moe_impl: str = "ragged",
             cache_dtype=jnp.float32) -> Tuple[np.ndarray, ServeStats]:
    """Greedy generation for a batch of fixed-length prompts (examples/tests).

    prompts: [B, S_prompt] int32 → generated [B, max_new_tokens].
    """
    B, Sp = prompts.shape
    max_len = Sp + max_new_tokens
    cache = lm.init_cache(cfg, B, max_len, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, moe_impl=moe_impl))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh, moe_impl=moe_impl))
    logits, cache = prefill(params, buffers, {"tokens": prompts}, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    outs = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = decode(params, buffers, nxt[:, None], cache)
        outs.append(nxt)
    from repro.core.cache import measured_cache_bytes
    stats = ServeStats(prefill_tokens=B * Sp, decoded_tokens=B * max_new_tokens,
                       cache_bytes=measured_cache_bytes(cache, B, max_len)["attn_bytes"])
    return np.stack([np.asarray(o) for o in outs], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching over the paged pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler steps (the
    simulated clock) — the Poisson driver maps wall arrival times onto it.

    Sampling is per request: ``temperature <= 0`` is greedy argmax; otherwise
    nucleus sampling from the smallest token set whose probability mass
    reaches ``top_p``, driven by a PRNG keyed on ``seed`` and folded with the
    token index — the same (seed, prompt) always yields the same tokens.
    """
    uid: int
    prompt: np.ndarray                    # [Sp] int32
    max_new_tokens: int
    arrival: float = 0.0
    temperature: float = 0.0              # 0 → greedy
    top_p: float = 1.0                    # nucleus mass (1 → full softmax)
    seed: int = 0                         # per-request PRNG seed
    # filled in by the scheduler:
    generated: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0                  # prefill-source tokens already cached
    prefill_src: Optional[np.ndarray] = None   # recompute source (None → prompt)
    swapped: Optional[Any] = None         # cache.SwappedSeq awaiting swap-in
    preempted_at: List[int] = dataclasses.field(default_factory=list)
    #   ^ len(generated) at each preemption (0 = preempted mid-prefill)
    spec_proposed: int = 0                # draft tokens proposed for this req
    spec_accepted: int = 0                # draft tokens that survived verify
    prefix_hit_tokens: int = 0            # prompt tokens served from the
                                          # prefix cache (Σ over re-admissions)
    submit_wall: float = 0.0
    first_token_wall: float = 0.0
    first_token_step: int = -1
    finish_step: int = -1
    finish_reason: str = ""               # "eos" | "budget"

    def prefill_source(self) -> np.ndarray:
        """Tokens that must be cached before decode (re)starts: the prompt,
        or — after a recompute preemption — prompt + generated prefix."""
        return self.prompt if self.prefill_src is None else self.prefill_src


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 4                    # concurrent sequences per decode step
    block_size: int = 16                  # tokens per pool block
    num_blocks: int = 128                 # pool capacity
    max_new_tokens: int = 64              # hard per-request generation cap
    max_len: int = 256                    # per-sequence token cap (table width)
    eos_id: Optional[int] = None
    prefill_bucket: int = 16              # prompts pad up to a multiple of this
    prefill_chunk_tokens: int = 0         # per-lane per-step chunk size
                                          # (0 → whole prompt at admission)
    prefill_batch_lanes: int = 0          # mid-prefill lanes packed per chunked
                                          # forward (0 → max_slots; 1 → PR-3
                                          # one-request-per-chunk behaviour)
    speculate_k: int = 0                  # draft tokens per resident per step
                                          # (0 → plain one-token decode)
    draft_rank: int = 0                   # joint-factor rank of the draft
                                          # model (0 or >= d_ckv → full rank)
    admission: str = "preempt"            # "preempt" | "watermark" (legacy)
    eviction: str = "recompute"           # "recompute" | "swap" (host swap-out)
    prefix_cache: bool = False            # share prompt-prefix blocks across
                                          # requests (COW on divergence)
    use_kernel: bool = True               # Pallas paged kernel on TPU
    cache_dtype: Any = jnp.float32        # pool page dtype; "int8" switches
                                          # the pool to symmetric absmax
                                          # quantization with per-token scales
                                          # (core/quant.py, docs/serving.md)
    sparse_topk_blocks: int = 0           # latent-space sparse decode: attend
                                          # only the top-k summary-scored
                                          # blocks per lane (0 → dense decode;
                                          # incompatible with speculate_k)
    sparse_recent_blocks: int = 2         # newest blocks always attended when
                                          # sparse decode is on (the local
                                          # window every selection keeps)

    @property
    def max_blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)

    @property
    def chunk_lanes(self) -> int:
        return self.prefill_batch_lanes or self.max_slots


def sample_tokens(logits, temps, top_ps, seeds, counts):
    """Batched per-request sampling for one decode step.

    logits [B,V] fp32-castable, temps/top_ps [B] fp32, seeds/counts [B] int32.
    Lane ``i`` draws from PRNG ``fold_in(PRNGKey(seeds[i]), counts[i])`` — the
    count is the request's token index, so replaying a request with the same
    seed reproduces its tokens regardless of which slot/step served it.
    ``temps[i] <= 0`` reduces exactly to greedy argmax.  → [B] int32.
    """

    def one(lg, temp, top_p, seed, count):
        def greedy(_):
            return jnp.argmax(lg).astype(jnp.int32)

        def sample(_):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
            scaled = lg.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
            order = jnp.argsort(-scaled)            # descending
            sl = scaled[order]
            probs = jax.nn.softmax(sl)
            # nucleus: drop tokens whose preceding cumulative mass already
            # covers top_p; the smallest covering set always keeps its first
            # member (even at the top_p <= 0 boundary, where the cut would
            # otherwise mask everything and sample from garbage)
            cut = (jnp.cumsum(probs) - probs) >= top_p
            cut = cut.at[0].set(False)
            sl = jnp.where(cut, -jnp.inf, sl)
            return order[jax.random.categorical(key, sl)].astype(jnp.int32)

        # temp <= 0 takes the argmax branch STRUCTURALLY — greedy lanes never
        # route through the temperature division, so "temperature 0" is exact
        # argmax rather than clamp-to-1e-6-shaped (tests/test_serve.py pins
        # greedy == temp-0 identity)
        return jax.lax.cond(temp <= 0.0, greedy, sample, None)

    return jax.vmap(one)(logits, temps, top_ps, seeds, counts)


# ---------------------------------------------------------------------------
# speculative-decode acceptance (pure functions — property-tested directly)
# ---------------------------------------------------------------------------

_ACCEPT_SALT = 0x5BEC                     # PRNG fold salts: the accept coin and
_RESID_SALT = 0x5BED                      # residual draw for one token index


def nucleus_probs(logits, temp: float, top_p: float) -> np.ndarray:
    """The exact categorical distribution ``sample_tokens`` draws from, as a
    dense probability vector (numpy, float64): temperature-scaled softmax
    restricted to the smallest descending-probability set whose mass reaches
    ``top_p`` (the set always keeps its first member).  Tokens outside the
    nucleus get probability exactly 0 — the rejection-sampling target/draft
    distributions for speculative decode."""
    scaled = np.asarray(logits, np.float64) / max(float(temp), 1e-6)
    order = np.argsort(-scaled, kind="stable")
    sl = scaled[order]
    e = np.exp(sl - sl.max())
    probs = e / e.sum()
    cut = (np.cumsum(probs) - probs) >= top_p
    cut[0] = False                        # first member survives even top_p=0
    sl = np.where(cut, -np.inf, sl)
    e = np.exp(sl - sl[0])                # sl[0] is always kept (finite max)
    p_sorted = e / e.sum()
    out = np.zeros_like(p_sorted)
    out[order] = p_sorted
    return out


def speculative_accept(token: int, p: np.ndarray, q: np.ndarray,
                       u: float) -> bool:
    """Distribution-preserving accept test for a draft ``token`` proposed
    from draft distribution ``q`` against target ``p``: accept iff
    ``u <= p(token)/q(token)`` (``u`` uniform on [0,1)).  Combined with
    ``residual_sample`` on rejection, the emitted token is distributed
    exactly as ``p`` (Leviathan et al.'s rejection-sampling identity).
    A token outside the *target* nucleus is never accepted, even when the
    host-side ``q`` disagrees with the device sampler's float32 nucleus cut
    at the top-p boundary and reports ``q(token) == 0`` (which would
    otherwise make the ratio vacuously pass)."""
    return p[token] > 0.0 and u * q[token] <= p[token]


def residual_sample(p: np.ndarray, q: np.ndarray, r: float) -> int:
    """Inverse-CDF draw from the normalized residual ``max(p - q, 0)`` — the
    corrected token after a rejection.  Support is a subset of ``p``'s
    (never a token outside the target nucleus).  Degenerate ``p == q``
    residuals (possible only through float rounding — exact equality always
    accepts) fall back to ``p`` itself."""
    res = np.maximum(np.asarray(p, np.float64) - np.asarray(q, np.float64), 0.0)
    if res.sum() <= 1e-12:
        res = np.asarray(p, np.float64)
    nz = np.flatnonzero(res)
    cdf = np.cumsum(res[nz]) / res[nz].sum()
    return int(nz[min(np.searchsorted(cdf, r, side="right"), len(nz) - 1)])


def _spec_uniform(seed: int, count: int, salt: int) -> float:
    """Uniform [0,1) tied to (request seed, token index, salt) — the same
    count-folded PRNG discipline as ``sample_tokens``, so acceptance
    decisions replay identically across preemption/recompute."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), count), salt)
    return float(jax.random.uniform(key))


def _prompt_buckets(finished: List[Request], edges: Tuple[int, ...]):
    """Partition finished requests by prompt length: yields
    ``(label, requests)`` per bucket — the single source of the bucket edges
    and labels every per-bucket metric (TTFT, acceptance) keys on, so the
    ``ttft_prompt_*`` and ``acc_prompt_*`` CSV columns can never
    desynchronize."""
    lo = 0
    for hi in tuple(edges) + (None,):
        label = (f"{lo + 1}-{hi}" if hi is not None else f">{lo}")
        yield label, [r for r in finished if lo < len(r.prompt)
                      and (hi is None or len(r.prompt) <= hi)]
        lo = hi if hi is not None else lo


def acceptance_by_prompt_bucket(finished: List[Request],
                                edges: Tuple[int, ...] = (16, 64)
                                ) -> Dict[str, float]:
    """Mean draft-acceptance rate per prompt-length bucket (same buckets as
    ``ttft_by_prompt_bucket``) — long-prompt windows attend to more context,
    so acceptance can drift with depth; the serving benchmark reports it."""
    out: Dict[str, float] = {}
    for label, rs in _prompt_buckets(finished, edges):
        rs = [r for r in rs if r.spec_proposed]
        if rs:
            out[label] = float(sum(r.spec_accepted for r in rs)
                               / sum(r.spec_proposed for r in rs))
    return out


def ttft_by_prompt_bucket(finished: List[Request],
                          edges: Tuple[int, ...] = (16, 64)) -> Dict[str, float]:
    """Mean TTFT (scheduler steps from arrival to first token) per prompt-
    length bucket — the quantity chunked prefill improves for *short* prompts
    that would otherwise queue behind long ones.  ``edges`` split lengths into
    len(edges)+1 buckets: <=16, 17..64, >64 by default."""
    out: Dict[str, float] = {}
    for label, rs in _prompt_buckets(finished, edges):
        if rs:
            out[label] = float(np.mean([r.first_token_step - r.arrival
                                        for r in rs]))
    return out


@dataclasses.dataclass
class ServeReport:
    """End-of-run scheduler metrics (docs/serving.md explains how to read
    them).  TTFT = arrival → first token; ``_steps`` is in simulated
    scheduler steps, ``_wall`` in wall milliseconds."""
    completed: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0               # prefill forward calls issued
    decoded_tokens: int = 0
    wall_s: float = 0.0
    tok_per_s: float = 0.0
    ttft_steps_mean: float = 0.0
    ttft_steps_by_bucket: Dict[str, float] = dataclasses.field(default_factory=dict)
    ttft_wall_p50_ms: float = 0.0
    ttft_wall_p95_ms: float = 0.0
    step_ms_p50: float = 0.0
    step_ms_p95: float = 0.0
    peak_slots: int = 0
    pool_high_water_blocks: int = 0
    pool_block_size: int = 0
    pool_dtype: str = "float32"           # page storage dtype ("int8" = quantized)
    pool_bytes_per_token: int = 0         # device bytes per pooled token (all
                                          # layers + streams, incl. scales)
    pool_allocated_bytes_peak: int = 0    # bytes at the block high-water mark
    naive_blocks: int = 0                 # Σ per-request worst-case blocks
    block_reuse_ratio: float = 0.0        # naive / high-water (>1 ⇒ paging won)
    admission: str = "preempt"            # policy the run used
    preemptions: int = 0                  # evictions forced by OutOfBlocks
    preempted_requests: int = 0           # distinct requests evicted ≥ once
    swap_outs: int = 0                    # preemptions served by host swap
    swap_ins: int = 0                     # swapped prefixes restored
    swapped_bytes: int = 0                # host↔device eviction traffic (out)
    mean_occupancy: float = 0.0           # mean fraction of pool blocks
                                          # REFERENCED by live chains (matches
                                          # what admission sees as busy)
    mean_occupancy_retained: float = 0.0  # mean fraction counting prefix-cache
                                          # retained (refcount-0 LRU) blocks
                                          # too — i.e. raw allocator usage
    mean_prefill_batch: float = 0.0       # mean lanes per chunked-prefill call
    speculate_k: int = 0                  # draft window size the run used
    draft_rank: int = 0                   # draft joint-factor rank (0 = full)
    draft_forwards: int = 0               # rank-truncated draft decode calls
    draft_proposed: int = 0               # draft tokens proposed across lanes
    draft_accepted: int = 0               # draft tokens that survived verify
    acceptance_rate: float = 0.0          # accepted / proposed
    mean_accepted: float = 0.0            # accepted draft tokens per window
    tokens_per_forward: float = 0.0       # tokens per lane per decode/verify
                                          # forward (plain ≡ 1.0; spec =
                                          # 1 + mean_accepted)
    acceptance_by_bucket: Dict[str, float] = dataclasses.field(default_factory=dict)
    prefix_cache: bool = False            # run shared prompt blocks
    prefix_cache_hits: int = 0            # admissions that reused cached blocks
    prefix_cache_misses: int = 0          # admissions finding nothing cached
    prefix_cache_hit_tokens: int = 0      # prompt tokens skipped at prefill
    prefix_cache_hit_rate: float = 0.0    # hit_tokens / tokens presented to
                                          # lookups (per-token, not per-request)
    cow_copies: int = 0                   # copy-on-write block privatizations
    blocks_retained: int = 0              # zero-ref cached blocks at run end
    sparse_topk: int = 0                  # block top-k the run decoded with
    sparse_recent: int = 0                # forced newest-block tail width
    sparse_steps: int = 0                 # decode forwards that ran sparse
    mean_selected_blocks: float = 0.0     # blocks attended per lane-step
    mean_candidate_blocks: float = 0.0    # resident blocks per lane-step
    phase_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    #   ^ wall ms per step phase over the whole run (keys == PHASES; a phase
    #     that never ran reports exactly 0.0).  ``other`` is the residual, so
    #     sum(phase_ms.values()) ≈ step_wall_ms_total.
    step_wall_ms_total: float = 0.0       # Σ wall ms of every step() call
    trace_events: int = 0                 # events emitted to the tracer
    trace_dropped: int = 0                # events the ring buffer evicted

    def phase_table(self) -> str:
        """One-line per-phase breakdown: ``phase=total_ms(share%)`` for every
        phase that ran (launch/serve.py prints it; ``trace-summary``
        reconstructs the same table from an exported timeline)."""
        total = max(self.step_wall_ms_total, 1e-9)
        parts = [f"{k}={v:.1f}ms({100 * v / total:.0f}%)"
                 for k, v in self.phase_ms.items() if v > 0]
        return " ".join(parts) if parts else "(no phases recorded)"

    def summary(self) -> str:
        bucket = "".join(f" ttft[{k}]={v:.1f}" for k, v in
                         self.ttft_steps_by_bucket.items())
        spec = ""
        if self.speculate_k:
            spec = (f" spec[k={self.speculate_k},r={self.draft_rank}] "
                    f"acc={self.acceptance_rate:.2f} "
                    f"tok/fwd={self.tokens_per_forward:.2f}")
        pc = ""
        if self.prefix_cache:
            pc = (f" pc[hit={self.prefix_cache_hit_rate:.2f} "
                  f"tok={self.prefix_cache_hit_tokens} "
                  f"cow={self.cow_copies}]")
        q8 = ""
        if self.pool_dtype not in ("float32", ""):
            q8 = (f" pool[{self.pool_dtype} "
                  f"{self.pool_bytes_per_token}B/tok]")
        sp = ""
        if self.sparse_topk:
            sp = (f" sparse[k={self.sparse_topk}+{self.sparse_recent} "
                  f"sel={self.mean_selected_blocks:.1f}/"
                  f"{self.mean_candidate_blocks:.1f}]")
        return (f"completed={self.completed} steps={self.decode_steps} "
                f"decoded={self.decoded_tokens} tok/s={self.tok_per_s:.1f} "
                f"ttft_steps={self.ttft_steps_mean:.1f}{bucket} "
                f"ttft_ms p50/p95={self.ttft_wall_p50_ms:.0f}/{self.ttft_wall_p95_ms:.0f} "
                f"step_ms p50/p95={self.step_ms_p50:.1f}/{self.step_ms_p95:.1f} "
                f"peak_slots={self.peak_slots} "
                f"blocks high-water/naive={self.pool_high_water_blocks}/"
                f"{self.naive_blocks} reuse×{self.block_reuse_ratio:.2f} "
                f"occ={self.mean_occupancy:.2f} [{self.admission}] "
                f"preempt={self.preemptions}"
                f"(swap {self.swap_outs}/{self.swap_ins}) "
                f"prefill_batch={self.mean_prefill_batch:.1f}{spec}{pc}{q8}{sp}")


class Scheduler:
    """Continuous-batching serving loop over the paged compressed cache."""

    def __init__(self, params, buffers, cfg: ModelConfig,
                 scfg: SchedulerConfig, mesh=None, moe_impl: str = "ragged",
                 tracer=None, metrics=None):
        assert cfg.elitekv.enabled, "paged serving requires an EliteKV config"
        assert scfg.eviction in ("recompute", "swap"), scfg.eviction
        # sparse decode scores single-token queries against block summaries;
        # the multi-query verify window has no single selection query, so the
        # speculative path stays dense — the combination is rejected outright
        # rather than silently ignoring one of the knobs
        assert scfg.sparse_topk_blocks == 0 or scfg.speculate_k == 0, \
            "sparse_topk_blocks and speculate_k are mutually exclusive"
        # recompute eviction re-prefills a preempted prefix DENSELY, but a
        # token generated under partial sparse decode carries layer>=1
        # streams shaped by sparse lower-layer attention — dense prefill
        # cannot reproduce them, so recompute would silently fork the
        # stream.  Swap restores the pages (and summary rows) byte-exactly;
        # full selection width is exactly dense, so either keeps the
        # preemption-invariance wall.  Reject the one unsound combination.
        sparse_partial = (0 < scfg.sparse_topk_blocks and
                          scfg.sparse_topk_blocks + scfg.sparse_recent_blocks
                          < scfg.max_blocks_per_seq)
        assert not (sparse_partial and scfg.admission == "preempt"
                    and scfg.eviction == "recompute"), \
            ("partial-width sparse decode requires eviction='swap' (or "
             "admission='watermark'): recompute prefill cannot reproduce "
             "sparse-generated streams")
        self.params, self.buffers, self.cfg, self.scfg = params, buffers, cfg, scfg
        self.trace = tracer or NULL_TRACER
        self.metrics = metrics or MetricsRegistry()
        # mesh=None serves single-device; a mesh with a >1 "model" axis
        # head-shards the k_e pages and runs decode/verify attention under
        # shard_map (kernels/ops.py TP wrappers) — token streams stay
        # bit-identical either way (tests/test_sharded_serving.py).
        self.pool = PagedKVPool(cfg, scfg.num_blocks, scfg.block_size,
                                dtype=scfg.cache_dtype, tracer=self.trace,
                                mesh=mesh,
                                block_summaries=scfg.sparse_topk_blocks > 0)
        self.bm = BlockManager(self.pool, policy=scfg.admission,
                               prefix_cache=scfg.prefix_cache)
        self.slots: List[Optional[Request]] = [None] * scfg.max_slots
        self.waiting: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self.t = 0                          # simulated clock (decode steps)
        self._step_wall_ms: List[float] = []
        self._occupancy: List[float] = []   # referenced fill fraction per step
        self._occupancy_retained: List[float] = []  # incl. LRU-retained blocks
        self.peak_slots = 0
        self.naive_blocks = 0
        self.prefill_chunks = 0             # prefill forward calls issued
        self._prefill_lanes_total = 0       # Σ live lanes over those calls
        self.draft_forwards = 0             # speculative: draft decode calls
        self.draft_proposed = 0             # Σ draft tokens proposed
        self.draft_accepted = 0             # Σ draft tokens accepted
        self._spec_windows = 0              # (lane, step) verify windows run
        self._lane_steps = 0                # Σ live lanes over decode forwards
        self._decode_appended = 0           # tokens appended by decode/verify
        # -- observability state (docs/observability.md) ---------------------
        self._phase_ms = {p: 0.0 for p in PHASES}
        self._step_wall_ms_total = 0.0
        m = self.metrics
        self._m_submitted = m.counter(
            "serve_requests_submitted_total", "requests submitted")
        self._m_completed = m.counter(
            "serve_requests_completed_total", "requests retired (eos|budget)")
        self._m_decoded = m.counter(
            "serve_tokens_decoded_total", "tokens appended by decode/verify")
        self._m_prefill_tokens = m.counter(
            "serve_prefill_tokens_total", "tokens cached by prefill forwards")
        self._m_preemptions = m.counter(
            "serve_preemptions_total", "residents evicted on OutOfBlocks")
        self._m_swap_outs = m.counter(
            "serve_swap_outs_total", "preemptions served by host swap-out")
        self._m_swap_ins = m.counter(
            "serve_swap_ins_total", "swapped prefixes restored to the pool")
        self._m_draft_proposed = m.counter(
            "serve_draft_proposed_total", "speculative draft tokens proposed")
        self._m_draft_accepted = m.counter(
            "serve_draft_accepted_total", "draft tokens that survived verify")
        self._m_blocks_used = m.gauge(
            "serve_pool_blocks_used",
            "pool blocks referenced by live chains (excludes prefix-cache "
            "retained blocks; see serve_prefix_cache_blocks_retained)")
        self._m_slots = m.gauge(
            "serve_slots_occupied", "scheduler slots currently resident")
        self._m_step_ms = m.histogram(
            "serve_step_ms", "decode/verify macro-step wall milliseconds")
        self._m_ttft_ms = m.histogram(
            "serve_ttft_ms", "request arrival to first token, wall ms")
        self._m_phase = {p: m.counter(f"serve_phase_{p}_ms_total",
                                      f"total wall ms spent in the {p} phase")
                         for p in PHASES}
        # prefix-cache family (always registered; zero-valued when the cache
        # is off so exported metric sets stay schema-stable for check_trace)
        self._m_pc_hits = m.counter(
            "serve_prefix_cache_hits_total",
            "admissions that reused >=1 cached prefix block")
        self._m_pc_misses = m.counter(
            "serve_prefix_cache_misses_total",
            "admissions whose prompt missed the prefix cache")
        self._m_pc_hit_tokens = m.counter(
            "serve_prefix_cache_hit_tokens_total",
            "prompt tokens served from cached blocks instead of prefill")
        self._m_pc_cow = m.counter(
            "serve_prefix_cache_cow_total",
            "copy-on-write block copies (write into a shared block)")
        self._m_pc_retained = m.gauge(
            "serve_prefix_cache_blocks_retained",
            "zero-refcount cached blocks held in the reclaimable LRU")
        self._m_pc_cached = m.gauge(
            "serve_prefix_cache_blocks_cached",
            "physical blocks with a registered prefix-hash claim")
        # pool family (always registered; a float pool reports quantized=0 so
        # exported metric sets stay schema-stable for check_trace — same
        # contract as the prefix-cache family above)
        self._pool_bpt = self.pool.bytes_per_token()
        self._m_pool_quantized = m.gauge(
            "serve_pool_quantized",
            "1 when the latent pool stores int8 rows + scales, else 0")
        self._m_pool_bpt = m.gauge(
            "serve_pool_bytes_per_token",
            "device bytes per pooled token across all layers and streams")
        self._m_pool_bytes = m.gauge(
            "serve_pool_allocated_bytes",
            "device bytes of pool blocks currently allocated to sequences")
        self._m_pool_quantized.set(1 if self.pool.quantized else 0)
        self._m_pool_bpt.set(self._pool_bpt)
        self._cow_synced = 0                # pool.cow_copies already metered
        # sparse-decode family — registered ONLY when sparse decode is on
        # (unlike the always-on families above, the summary leaves and
        # selection stage simply don't exist in a dense run; check_trace
        # enforces the family all-or-nothing instead of always-present)
        self._sparse_steps = 0              # decode forwards with sparse on
        self._sparse_selected = 0           # Σ blocks attended across lanes
        self._sparse_candidate = 0          # Σ resident blocks across lanes
        if scfg.sparse_topk_blocks > 0:
            self._m_sparse_topk = m.gauge(
                "serve_sparse_topk",
                "top-k blocks scored into each sparse decode selection")
            self._m_sparse_recent = m.gauge(
                "serve_sparse_recent",
                "newest blocks always attended by sparse decode")
            self._m_sparse_steps = m.counter(
                "serve_sparse_steps_total",
                "decode forwards that ran with sparse block selection")
            self._m_sparse_selected = m.counter(
                "serve_sparse_selected_blocks_total",
                "blocks attended across all sparse-decode lanes")
            self._m_sparse_candidate = m.counter(
                "serve_sparse_candidate_blocks_total",
                "resident blocks eligible across all sparse-decode lanes")
            self._m_sparse_hist = m.histogram(
                "serve_sparse_selected_blocks",
                "blocks attended per lane per sparse decode forward")
            self._m_sparse_topk.set(scfg.sparse_topk_blocks)
            self._m_sparse_recent.set(scfg.sparse_recent_blocks)
        # the draft shares params unless a real rank truncation is requested
        self.draft_params = (
            lm.make_draft_params(params, cfg, scfg.draft_rank)
            if scfg.speculate_k > 0 else None)

        def _prefill(params, buffers, tokens, pages, slot_mapping):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping, moe_impl=moe_impl,
                                          mesh=mesh)

        def _prefill_batch(params, buffers, tokens, pages, slot_mapping,
                           chunk_starts, block_tables, prefix_lens):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping,
                                          chunk_start=chunk_starts,
                                          block_tables=block_tables,
                                          prefix_lens=prefix_lens,
                                          block_size=scfg.block_size,
                                          moe_impl=moe_impl, mesh=mesh)

        def _decode(params, buffers, tokens, pages, slot_mapping,
                    block_tables, lengths):
            return lm.apply_decode_paged(params, buffers, cfg,
                                         {"tokens": tokens}, pages,
                                         slot_mapping, block_tables, lengths,
                                         block_size=scfg.block_size,
                                         use_kernel=scfg.use_kernel,
                                         sparse_topk=scfg.sparse_topk_blocks,
                                         sparse_recent=scfg.sparse_recent_blocks,
                                         moe_impl=moe_impl, mesh=mesh)

        def _verify(params, buffers, tokens, pages, slot_mapping,
                    block_tables, q_offsets, lengths):
            return lm.apply_verify_paged(params, buffers, cfg,
                                         {"tokens": tokens}, pages,
                                         slot_mapping, block_tables,
                                         q_offsets, lengths,
                                         block_size=scfg.block_size,
                                         use_kernel=scfg.use_kernel,
                                         moe_impl=moe_impl, mesh=mesh)

        # donate the pages so XLA updates the pool in place rather than
        # copying every block each step (donation is unsupported + noisy on CPU)
        donate = () if jax.default_backend() == "cpu" else (3,)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._prefill_batch = jax.jit(_prefill_batch, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._verify = jax.jit(_verify, donate_argnums=donate)
        self._sample = jax.jit(sample_tokens)

    # -- observability ------------------------------------------------------
    @contextlib.contextmanager
    def _phase(self, name: str, **args):
        """Attribute the enclosed wall time to step phase ``name`` — into
        ``phase_ms``, the metrics registry, and (when tracing) a span on the
        ``scheduler`` track.  Phases never nest (the residual ``other`` would
        double-count), which tools/check_trace.py can verify from the
        exported timeline."""
        with self.trace.span(name, track="scheduler", cat="phase", **args):
            t0 = time.perf_counter()
            try:
                yield
            finally:
                dt_ms = (time.perf_counter() - t0) * 1e3
                self._phase_ms[name] += dt_ms
                self._m_phase[name].inc(dt_ms)

    def _measured_phase_ms(self) -> float:
        return sum(v for k, v in self._phase_ms.items() if k != "other")

    def _stuck_report(self, max_steps: int) -> str:
        """Diagnostic payload for the did-not-drain failure: per-request
        status of every resident and waiter plus the tracer's recent event
        tail, so a stuck-pool run is debuggable from the exception alone."""
        lines = [f"scheduler did not drain in {max_steps} steps"]
        lines.append(f"pool: {self.pool.allocator.num_used}/"
                     f"{self.pool.num_blocks} blocks used, "
                     f"{self.pool.allocator.num_free} free, "
                     f"block_size={self.pool.block_size}")
        if self.bm.prefix is not None:
            pc = self.bm.prefix
            lines.append(f"prefix cache: {pc.num_cached} cached, "
                         f"{pc.num_retained} retained, hits={pc.hits} "
                         f"misses={pc.misses} cow={self.pool.cow_copies}")
        for i, r in enumerate(self.slots):
            if r is None:
                lines.append(f"slot{i}: empty")
                continue
            lines.append(
                f"slot{i}: uid={r.uid} prefill={r.prefill_pos}/"
                f"{len(r.prefill_source())} generated="
                f"{len(r.generated)}/{r.max_new_tokens} "
                f"pool_len={self.pool.length(r.uid)} "
                f"blocks={len(self.pool.block_table(r.uid))} "
                f"preempted={len(r.preempted_at)}x")
        for r in list(self.waiting)[:8]:
            lines.append(f"waiting: uid={r.uid} arrival={r.arrival:.1f} "
                         f"prefill_src={len(r.prefill_source())} "
                         f"swapped={r.swapped is not None} "
                         f"preempted={len(r.preempted_at)}x")
        if len(self.waiting) > 8:
            lines.append(f"waiting: … {len(self.waiting) - 8} more")
        lines.append(self.trace.format_tail(40))
        return "\n".join(lines)

    # -- request intake -----------------------------------------------------
    def submit(self, req: Request) -> None:
        req.max_new_tokens = min(req.max_new_tokens, self.scfg.max_new_tokens)
        assert len(req.prompt) + req.max_new_tokens <= self.scfg.max_len, \
            (len(req.prompt), req.max_new_tokens, self.scfg.max_len)
        if self._worst_case_blocks(req) > self.scfg.num_blocks:
            raise OutOfBlocks(
                f"request {req.uid} needs {self._worst_case_blocks(req)} blocks "
                f"worst-case but the pool only has {self.scfg.num_blocks} — "
                f"it could never be admitted")
        req.submit_wall = time.perf_counter()
        self.waiting.append(req)
        self.naive_blocks += self._worst_case_blocks(req)
        self._m_submitted.inc()
        self.trace.instant("submit", track="scheduler", cat="request",
                           uid=req.uid, prompt=len(req.prompt),
                           budget=req.max_new_tokens, arrival=req.arrival)

    def _worst_case_blocks(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens) // self.scfg.block_size)

    def _first_alloc_tokens(self, req: Request) -> int:
        """Pool tokens the request needs *immediately* at admission: the
        swapped-out prefix being restored, the first prefill chunk, or (one-
        shot mode) the whole prefill source."""
        if req.swapped is not None:
            return req.swapped.length
        src = len(req.prefill_source())
        chunk = self.scfg.prefill_chunk_tokens
        return min(chunk, src) if chunk > 0 else src

    # -- admission ----------------------------------------------------------
    def _try_admit(self) -> int:
        admitted = 0
        while self.waiting and self.waiting[0].arrival <= self.t:
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                break
            req = self.waiting[0]
            if not self.bm.can_admit(self._first_alloc_tokens(req),
                                     self._worst_case_blocks(req)):
                break                       # head-of-line waits for blocks
            self.waiting.popleft()
            self._admit(slot, req)
            admitted += 1
        return admitted

    def _admit(self, slot: int, req: Request) -> None:
        """Claim a slot (restoring a swapped-out prefix if there is one).
        Block allocation otherwise happens on demand, chunk by chunk, in
        ``_prefill_work`` — and prefill itself is interleaved with decode.
        With the prefix cache on, a fresh (non-swapped) admission first
        probes the cache with its prefill source: hit blocks splice into the
        chain and ``prefill_pos`` jumps past them, so prefill resumes at the
        first miss (the final prompt token is never cache-served — its
        logits row seeds the first sampled token)."""
        if req.swapped is not None:
            with self._phase("swap", direction="in", uid=req.uid):
                self.bm.swap_in(req.uid, req.swapped)
            req.swapped = None
            self._m_swap_ins.inc()
        elif self.bm.prefix is not None and req.prefill_pos == 0:
            self._lookup_prefix(req)
        self.bm.register(req.uid, self._worst_case_blocks(req))
        self.slots[slot] = req
        self.trace.begin(f"req{req.uid}", track=f"slot{slot}", cat="request",
                         uid=req.uid)
        self.trace.instant("admit", track="scheduler", cat="request",
                           uid=req.uid, slot=slot,
                           queued_steps=self.t - req.arrival)

    def _lookup_prefix(self, req: Request) -> None:
        """Probe the prefix cache with the request's prefill source and
        splice any hit blocks into its (fresh) chain.  After a recompute
        preemption the source is prompt + generated, so a re-admission can
        hit its *own* earlier blocks (retained at eviction) and skip most of
        the recompute prefill."""
        src = req.prefill_source()
        hit = self.bm.lookup_prefix(req.uid, src)
        if hit:
            req.prefill_pos = hit
            req.prefix_hit_tokens += hit
            self._m_pc_hits.inc()
            self._m_pc_hit_tokens.inc(hit)
            self.trace.instant("prefix_hit", track="scheduler", cat="cache",
                               uid=req.uid, tokens=hit,
                               blocks=hit // self.scfg.block_size)
        else:
            self._m_pc_misses.inc()
            self.trace.instant("prefix_miss", track="scheduler", cat="cache",
                               uid=req.uid, tokens=len(src))

    # -- preemption ---------------------------------------------------------
    def _decode_ready(self, req: Request) -> bool:
        """Prefill source fully cached and the next input token sampled."""
        return bool(req.generated) and \
            req.prefill_pos >= len(req.prefill_source())

    def _youngest_slot(self) -> Optional[int]:
        occ = [(s.arrival, s.uid, i)
               for i, s in enumerate(self.slots) if s is not None]
        return max(occ)[2] if occ else None

    def _preempt(self, slot: int) -> None:
        """Evict the resident in ``slot`` and requeue it at the head of the
        waiting line.  ``eviction="recompute"`` frees its blocks and arms a
        recompute-prefill over prompt + generated-so-far (whose final logits
        re-produce exactly the token the interrupted decode step would have);
        ``eviction="swap"`` copies the cached prefix to host memory instead,
        restored block-exactly at re-admission."""
        req = self.slots[slot]
        req.preempted_at.append(len(req.generated))
        if self.scfg.eviction == "swap":
            # cached tokens from *request* state: prompt + generated minus the
            # not-yet-written last token (decode-ready), or the prefill cursor
            if self._decode_ready(req):
                cached = len(req.prompt) + len(req.generated) - 1
                req.prefill_src = np.concatenate(
                    [req.prompt,
                     np.asarray(req.generated[:-1], np.int32)])
                req.prefill_pos = cached
            else:
                cached = req.prefill_pos
            with self._phase("swap", direction="out", uid=req.uid):
                req.swapped = self.bm.preempt_swap_out(req.uid, cached)
            if req.swapped is not None:
                self._m_swap_outs.inc()
        else:
            if req.generated:
                req.prefill_src = np.concatenate(
                    [req.prompt, np.asarray(req.generated, np.int32)])
            req.prefill_pos = 0
            self.bm.preempt_recompute(req.uid)
        self._m_preemptions.inc()
        self.trace.end(f"req{req.uid}", track=f"slot{slot}", cat="request",
                       reason="preempt")
        self.trace.instant("preempt", track="scheduler", cat="request",
                           uid=req.uid, slot=slot, mode=self.scfg.eviction,
                           generated=len(req.generated))
        self.slots[slot] = None
        self.waiting.appendleft(req)

    def _grow_or_preempt(self, req: Request, length: int,
                         write_from: Optional[int] = None) -> bool:
        """Grow ``req``'s chain to ``length`` tokens, preempting the youngest
        resident until the allocation fits.  Returns False iff ``req`` itself
        was the youngest and got evicted (caller drops it this step).
        Terminates: every retry removes one resident, and a lone resident's
        worst case fits the pool (enforced at ``submit``).

        ``write_from`` is the copy-on-write barrier: the caller is about to
        write pool positions ``[write_from, length)``, so any *shared* block
        covering that range is privatized first (``BlockManager.
        prepare_write``).  The COW copy itself allocates, so it lives inside
        the same OutOfBlocks-preempt retry loop as the growth."""
        while True:
            try:
                self.bm.grow(req.uid, length)
                if write_from is not None:
                    self.bm.prepare_write(req.uid, write_from, length)
                return True
            except OutOfBlocks:
                slot = self._youngest_slot()
                if slot is None:
                    raise
                victim = self.slots[slot]
                self._preempt(slot)
                if victim is req:
                    return False

    # -- single-row sampling ------------------------------------------------
    def _sample_one(self, req: Request, row, count: int) -> int:
        """One token from a single logits row with ``req``'s sampling params
        and the count-folded PRNG — exactly the draw the batched decode
        sampler would make for token index ``count``.  The single source of
        the per-token PRNG discipline for the prefill first-token and the
        speculative bonus token (the golden preemption/speculation stream
        invariants both hang off it)."""
        if req.temperature <= 0:
            return int(np.argmax(np.asarray(row)))
        return int(np.asarray(self._sample(
            jnp.asarray(row)[None],
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_p], jnp.float32),
            jnp.asarray([req.seed], jnp.int32),
            jnp.asarray([count], jnp.int32)))[0])

    # -- chunked / batched prefill ------------------------------------------
    def _sample_prefill_token(self, req: Request, last_row) -> None:
        """Sample the token that follows a completed (re)prefill from its
        final logits row.  The PRNG count is ``len(generated)``: 0 for a
        fresh prompt (the request's first token), ``k`` after a recompute —
        re-drawing exactly the token the interrupted decode step would have
        produced, so preemption never changes the stream.  (Speculative
        caveat: with a truncated draft at temperature > 0 the interrupted
        token may originally have come through the accept/residual path,
        whose outcome depends on window alignment — the redraw here keeps
        the stream correctly *distributed* but, like any window-alignment
        shift, can change the realized sample; greedy and full-rank-draft
        streams are exactly invariant.)"""
        tok = self._sample_one(req, last_row, len(req.generated))
        req.generated.append(tok)
        self._m_decoded.inc()               # prefill-sampled tokens count too
        if req.first_token_step < 0:        # TTFT survives preemption
            req.first_token_wall = time.perf_counter()
            req.first_token_step = self.t
            self._m_ttft_ms.observe((req.first_token_wall - req.submit_wall)
                                    * 1e3)
            self.trace.instant("first_token", track="scheduler",
                               cat="request", uid=req.uid, step=self.t)

    def _run_oneshot(self, slot: int, req: Request) -> None:
        """Whole-source causal prefill in one call, padded to the bucket.
        A prefix-cache hit leaves ``prefill_pos > 0``: only the uncovered
        tail runs, as one resumed chunk attending to the cached prefix
        through the block table (the chunked machinery's ``chunk_start`` /
        ``prefix_lens`` path with a single lane)."""
        src = req.prefill_source()
        sp = len(src)
        pos = req.prefill_pos
        if not self._grow_or_preempt(req, sp, write_from=pos):
            return                          # req evicted itself — retry later
        n = sp - pos
        pad = -(-n // self.scfg.prefill_bucket) * self.scfg.prefill_bucket
        tokens = np.zeros((1, pad), np.int32)
        tokens[0, :n] = src[pos:]
        sm = self.pool.prefill_slot_mapping(req.uid, pos, n, pad)[None]
        with self._phase("prefill", lanes=1, tokens=n):
            if pos == 0:
                logits, self.pool.pages = self._prefill(
                    self.params, self.buffers, jnp.asarray(tokens),
                    self.pool.pages, jnp.asarray(sm))
            else:
                bt = self.pool.block_table_array(
                    [req.uid], self.scfg.max_blocks_per_seq)
                starts = np.asarray([pos], np.int32)
                logits, self.pool.pages = self._prefill_batch(
                    self.params, self.buffers, jnp.asarray(tokens),
                    self.pool.pages, jnp.asarray(sm), jnp.asarray(starts),
                    jnp.asarray(bt), jnp.asarray(starts))
            jax.block_until_ready(logits)
        self.trace.instant("prefill_chunk", track=f"slot{slot}",
                           cat="request", uid=req.uid, start=pos, n=n)
        self._m_prefill_tokens.inc(n)
        req.prefill_pos = sp
        if self.bm.prefix is not None:
            self.bm.register_prefix(req.uid, src[:sp])
        self.prefill_chunks += 1
        self._prefill_lanes_total += 1
        with self._phase("sample"):
            self._sample_prefill_token(req, logits[0, n - 1])
        self._maybe_finish(slot, req.generated[-1])

    def _prefill_work(self) -> None:
        """Advance mid-prefill residents.  One-shot mode (``chunk == 0``):
        each pending prompt prefills whole, FCFS.  Chunked mode: pack the
        next ``prefill_chunk_tokens``-token chunk of up to ``chunk_lanes``
        lanes (FCFS by arrival) into ONE fixed-shape forward — per-lane
        ``chunk_start``/``prefix_lens`` vectors give every lane its own
        offset causal mask against its own paged prefix."""
        scfg = self.scfg
        chunk = scfg.prefill_chunk_tokens
        if chunk <= 0:
            while True:
                cand = [(s.arrival, s.uid, i)
                        for i, s in enumerate(self.slots)
                        if s is not None
                        and s.prefill_pos < len(s.prefill_source())]
                if not cand:
                    return
                _, _, slot = min(cand)
                self._run_oneshot(slot, self.slots[slot])
        # chunked: FCFS-select lanes, growing each chain for its chunk
        # (growth may preempt residents — including already-selected lanes)
        cand = sorted((s.arrival, s.uid, i)
                      for i, s in enumerate(self.slots)
                      if s is not None
                      and s.prefill_pos < len(s.prefill_source()))
        selected: List[Tuple[int, Request, int, int]] = []
        for _, _, slot in cand:
            if len(selected) >= scfg.chunk_lanes:
                break
            req = self.slots[slot]
            if req is None:                 # evicted by an earlier growth
                continue
            n = min(chunk, len(req.prefill_source()) - req.prefill_pos)
            if self._grow_or_preempt(req, req.prefill_pos + n,
                                     write_from=req.prefill_pos):
                selected.append((slot, req, req.prefill_pos, n))
        selected = [(s, r, st, n) for s, r, st, n in selected
                    if self.slots[s] is r]  # drop lanes evicted after selection
        if not selected:
            return
        lanes = scfg.chunk_lanes
        tokens = np.zeros((lanes, chunk), np.int32)
        sms = np.full((lanes, chunk), self.pool.oob_slot, np.int32)
        starts = np.zeros((lanes,), np.int32)
        seq_ids: List[Optional[int]] = [None] * lanes
        for lane, (slot, req, start, n) in enumerate(selected):
            tokens[lane, :n] = req.prefill_source()[start:start + n]
            sms[lane] = self.pool.prefill_slot_mapping(req.uid, start, n, chunk)
            starts[lane] = start            # chunk offset == cached prefix len
            seq_ids[lane] = req.uid
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)
        n_toks = sum(n for _, _, _, n in selected)
        with self._phase("prefill", lanes=len(selected), tokens=n_toks):
            logits, self.pool.pages = self._prefill_batch(
                self.params, self.buffers, jnp.asarray(tokens), self.pool.pages,
                jnp.asarray(sms), jnp.asarray(starts), jnp.asarray(bt),
                jnp.asarray(starts))
            jax.block_until_ready(logits)
        self._m_prefill_tokens.inc(n_toks)
        self.prefill_chunks += 1
        self._prefill_lanes_total += len(selected)
        for lane, (slot, req, start, n) in enumerate(selected):
            self.trace.instant("prefill_chunk", track=f"slot{slot}",
                               cat="request", uid=req.uid, start=start, n=n)
            req.prefill_pos = start + n
            if self.bm.prefix is not None:
                # register freshly completed full prompt blocks after every
                # chunk, so requests arriving mid-prefill can already hit
                self.bm.register_prefix(
                    req.uid, req.prefill_source()[:req.prefill_pos])
            if req.prefill_pos >= len(req.prefill_source()):
                with self._phase("sample"):
                    self._sample_prefill_token(req, logits[lane, n - 1])
                self._maybe_finish(slot, req.generated[-1])

    # -- retirement ---------------------------------------------------------
    def _maybe_finish(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        if self.scfg.eos_id is not None and token == self.scfg.eos_id:
            req.finish_reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            req.finish_reason = "budget"
        else:
            return
        req.finish_step = self.t
        self.bm.release(req.uid)            # blocks recycle immediately
        self.finished.append(req)
        self.slots[slot] = None
        self._m_completed.inc()
        self.trace.end(f"req{req.uid}", track=f"slot{slot}", cat="request",
                       reason=req.finish_reason)
        self.trace.instant("retire", track="scheduler", cat="request",
                           uid=req.uid, reason=req.finish_reason,
                           tokens=len(req.generated))

    def _blocks_referenced(self) -> int:
        """Pool blocks referenced by live chains — allocator usage minus the
        refcount-0 blocks the prefix cache merely retains for reuse (those
        are reclaimable, and admission already treats them as free)."""
        retained = self.bm.prefix.num_retained if self.bm.prefix else 0
        return self.pool.allocator.num_used - retained

    # -- one scheduler iteration -------------------------------------------
    def step(self) -> bool:
        """Admit + chunk-prefill + decode (or draft/verify) once.  Returns
        False when drained."""
        self._try_admit()
        self._prefill_work()
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        self.peak_slots = max(self.peak_slots, len(occupied))
        # "used" means referenced by a live chain: prefix-cache-retained
        # blocks (refcount 0, LRU-parked) are reclaimable on demand, so they
        # count as free for admission and must not show as in-use here —
        # they are reported separately via serve_prefix_cache_blocks_retained.
        referenced = self._blocks_referenced()
        self._m_blocks_used.set(referenced)
        self._m_slots.set(len(occupied))
        self.trace.counter("pool_blocks_used", referenced, track="pool")
        alloc_bytes = (self.pool.allocator.num_used * self.scfg.block_size
                       * self._pool_bpt)
        self._m_pool_bytes.set(alloc_bytes)
        self.trace.counter("pool_allocated_bytes", alloc_bytes, track="pool")
        self.trace.counter("slots_occupied", len(occupied), track="scheduler")
        if self.bm.prefix is not None:
            if self.pool.cow_copies > self._cow_synced:
                self._m_pc_cow.inc(self.pool.cow_copies - self._cow_synced)
                self._cow_synced = self.pool.cow_copies
            self._m_pc_retained.set(self.bm.prefix.num_retained)
            self._m_pc_cached.set(self.bm.prefix.num_cached)
            self.trace.counter("prefix_blocks_retained",
                               self.bm.prefix.num_retained, track="pool")
        # decode lanes: slots whose prefill source is fully cached, oldest
        # first — chain growth may preempt the youngest residents (who then
        # sit out this step in the queue).
        order = sorted((self.slots[i].arrival, self.slots[i].uid, i)
                       for i in occupied if self._decode_ready(self.slots[i]))
        if self.scfg.speculate_k > 0:
            progressed = self._speculative_step(order)
        else:
            progressed = self._decode_step(order)
        if not progressed:
            if all(s is None for s in self.slots) and not self.waiting:
                return False
            self.t += 1                     # waiting on arrivals or prefill
            return True
        self.t += 1
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def _decode_step(self, order) -> bool:
        """Plain one-token decode over every decode-ready lane (one forward).
        Returns False when no lane was live (waiting on arrivals/prefill)."""
        grown: Dict[int, int] = {}          # slot → position of the new token
        for _, _, i in order:
            req = self.slots[i]
            if req is None:
                continue                    # evicted by an older lane's growth
            cur = self.pool.length(req.uid)
            if self._grow_or_preempt(req, cur + 1, write_from=cur):
                grown[i] = cur
        active = [i for i in grown if self.slots[i] is not None]
        self._occupancy.append(
            self._blocks_referenced() / self.pool.num_blocks)
        self._occupancy_retained.append(
            self.pool.allocator.num_used / self.pool.num_blocks)
        if not active:
            return False

        scfg = self.scfg
        B = scfg.max_slots
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ps = np.ones((B,), np.float32)
        seeds = np.zeros((B,), np.int32)
        counts = np.zeros((B,), np.int32)
        seq_ids: List[Optional[int]] = [None] * B
        positions = [0] * B
        for i in active:
            req = self.slots[i]
            cur = grown[i]                  # chain already grown above
            tokens[i, 0] = req.generated[-1]
            lengths[i] = cur + 1
            seq_ids[i] = req.uid
            positions[i] = cur
            temps[i] = req.temperature
            top_ps[i] = req.top_p
            seeds[i] = req.seed
            counts[i] = len(req.generated)  # token index within the request
        sm = self.pool.slot_mapping(seq_ids, positions)
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)

        t0 = time.perf_counter()
        with self._phase("decode", lanes=len(active)):
            logits, self.pool.pages = self._decode(self.params, self.buffers,
                                                   jnp.asarray(tokens),
                                                   self.pool.pages,
                                                   jnp.asarray(sm), jnp.asarray(bt),
                                                   jnp.asarray(lengths))
            jax.block_until_ready(logits)
        with self._phase("sample"):
            if np.any(temps > 0):
                nxt = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(temps),
                                              jnp.asarray(top_ps),
                                              jnp.asarray(seeds),
                                              jnp.asarray(counts)))
            else:                           # all-greedy step: skip the
                nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))  # sampler
        self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)
        self._m_step_ms.observe(self._step_wall_ms[-1])
        self._lane_steps += len(active)
        if scfg.sparse_topk_blocks > 0:
            # the forward already ran the selection on device; mirror the
            # arithmetic here (ceil-div resident chain vs. selection width)
            # rather than pulling sel_tables back across the transfer fence
            bs = scfg.block_size
            width = min(scfg.sparse_topk_blocks + scfg.sparse_recent_blocks,
                        scfg.max_blocks_per_seq)
            step_sel = step_cand = 0
            for i in active:
                n_chain = -(-int(lengths[i]) // bs)
                sel = min(width, n_chain)
                step_sel += sel
                step_cand += n_chain
                self._m_sparse_hist.observe(sel)
            self._sparse_steps += 1
            self._sparse_selected += step_sel
            self._sparse_candidate += step_cand
            self._m_sparse_steps.inc()
            self._m_sparse_selected.inc(step_sel)
            self._m_sparse_candidate.inc(step_cand)
            self.trace.instant("sparse_select", track="pool", cat="cache",
                               selected=step_sel, candidate=step_cand)
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self._decode_appended += 1
            self._m_decoded.inc()
            self._maybe_finish(i, tok)
        return True

    # -- speculative decode: draft / verify macro-step -----------------------
    def _speculative_step(self, order) -> bool:
        """Draft + verify for every decode-ready lane (docs/serving.md):

        1. grow each lane's chain for its whole window up front (``w`` draft
           slots + the pending token's slot, ``w = min(k, budget left)``) —
           growth may preempt, exactly like plain decode's one-token growth;
        2. ``k`` sequential decode forwards of the rank-truncated draft
           propose tokens (batched over lanes; draft streams scatter into the
           pool so later draft tokens attend to earlier ones);
        3. ONE full-model verify forward re-scores all ``k+1`` window
           positions per lane against the paged prefix — overwriting the
           window's pool slots with full-model streams;
        4. per lane, accept a prefix by rejection sampling (greedy: exact
           argmax match) and roll the chain back over rejected tokens via
           ``BlockManager.truncate``.

        Between steps the request/pool invariant is exactly plain decode's
        (cache = prompt + generated[:-1], last token pending), so preemption,
        swap and recompute machinery work unchanged."""
        scfg = self.scfg
        k = scfg.speculate_k
        B = scfg.max_slots
        W = k + 1
        windows: Dict[int, Tuple[int, int]] = {}   # slot → (cur, w)
        for _, _, i in order:
            req = self.slots[i]
            if req is None:
                continue                    # evicted by an older lane's growth
            cur = self.pool.length(req.uid)
            w = min(k, req.max_new_tokens - len(req.generated))
            if self._grow_or_preempt(req, cur + w + 1, write_from=cur):
                windows[i] = (cur, w)
        active = [i for i in windows if self.slots[i] is not None]
        self._occupancy.append(
            self._blocks_referenced() / self.pool.num_blocks)
        self._occupancy_retained.append(
            self.pool.allocator.num_used / self.pool.num_blocks)
        if not active:
            return False

        t0 = time.perf_counter()
        # block tables are invariant for the whole macro-step (every chain
        # was grown to its full window above): build them once, reuse for
        # all k draft forwards and the verify forward.  Lanes that fall out
        # of a shorter window mid-draft are masked by length 0 + oob slots.
        seq_ids_act: List[Optional[int]] = [None] * B
        for i in active:
            seq_ids_act[i] = self.slots[i].uid
        bt = jnp.asarray(self.pool.block_table_array(
            seq_ids_act, scfg.max_blocks_per_seq))
        # -- draft: k cheap truncated-rank decode forwards, batched over lanes
        drafts: Dict[int, List[int]] = {i: [] for i in active}
        dlogits: Dict[int, List[np.ndarray]] = {i: [] for i in active}
        xs = {i: self.slots[i].generated[-1] for i in active}
        for j in range(k):
            live = [i for i in active if windows[i][1] > j]
            if not live:
                break
            tokens = np.zeros((B, 1), np.int32)
            lengths = np.zeros((B,), np.int32)
            temps = np.zeros((B,), np.float32)
            top_ps = np.ones((B,), np.float32)
            seeds = np.zeros((B,), np.int32)
            counts = np.zeros((B,), np.int32)
            seq_ids: List[Optional[int]] = [None] * B
            positions = [0] * B
            for i in live:
                req = self.slots[i]
                cur, _ = windows[i]
                tokens[i, 0] = xs[i]
                lengths[i] = cur + j + 1
                seq_ids[i] = req.uid
                positions[i] = cur + j
                temps[i] = req.temperature
                top_ps[i] = req.top_p
                seeds[i] = req.seed
                counts[i] = len(req.generated) + j  # index of the proposal
            sm = self.pool.slot_mapping(seq_ids, positions)
            with self._phase("draft", j=j, lanes=len(live)):
                logits, self.pool.pages = self._decode(
                    self.draft_params, self.buffers, jnp.asarray(tokens),
                    self.pool.pages, jnp.asarray(sm), bt,
                    jnp.asarray(lengths))
                self.draft_forwards += 1
                sampled = bool(np.any(temps > 0))
                if sampled:
                    nxt = np.asarray(self._sample(
                        logits[:, -1, :], jnp.asarray(temps),
                        jnp.asarray(top_ps),
                        jnp.asarray(seeds), jnp.asarray(counts)))
                    # draft distributions are only needed for the accept
                    # ratio — all-greedy macro-steps skip the host transfer
                    rows = np.asarray(logits[:, -1, :])
                else:
                    nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
                    rows = None
            for i in live:
                drafts[i].append(int(nxt[i]))
                if rows is not None:
                    dlogits[i].append(rows[i])
                xs[i] = int(nxt[i])

        # -- verify: all k+1 window positions per lane in ONE forward --------
        tokens = np.zeros((B, W), np.int32)
        sms = np.full((B, W), self.pool.oob_slot, np.int32)
        offs = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        for i in active:
            req = self.slots[i]
            cur, w = windows[i]
            tokens[i, 0] = req.generated[-1]
            tokens[i, 1:1 + w] = drafts[i][:w]
            sms[i] = self.pool.prefill_slot_mapping(req.uid, cur, w + 1, W)
            offs[i] = cur
            lengths[i] = cur + w + 1
        with self._phase("verify", lanes=len(active)):
            logits, self.pool.pages = self._verify(
                self.params, self.buffers, jnp.asarray(tokens), self.pool.pages,
                jnp.asarray(sms), bt, jnp.asarray(offs),
                jnp.asarray(lengths))
            rows_all = np.asarray(logits)
        self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)
        self._m_step_ms.observe(self._step_wall_ms[-1])
        self._lane_steps += len(active)

        # -- accept a prefix per lane, roll the chain back over the rest -----
        with self._phase("accept", lanes=len(active)):
            for i in active:
                req = self.slots[i]
                cur, w = windows[i]
                out = self._accept_window(req, drafts[i][:w], dlogits[i][:w],
                                          rows_all[i])
                n_acc = len(out) - 1
                self.bm.truncate(req.uid, cur + n_acc + 1)
                appended = 0
                for tok in out:
                    req.generated.append(tok)
                    appended += 1
                    self._maybe_finish(i, tok)
                    if self.slots[i] is None:
                        break               # EOS/budget mid-window: rest drops
                self._decode_appended += appended
                self._m_decoded.inc(appended)
                # count only accepted drafts that were actually *kept* — an
                # EOS cutting an accepted prefix short must not inflate
                # acceptance (keeps tokens_per_forward == 1 + mean_accepted
                # away from EOS)
                kept = min(n_acc, appended)
                req.spec_proposed += w
                req.spec_accepted += kept
                self.draft_proposed += w
                self.draft_accepted += kept
                self._m_draft_proposed.inc(w)
                self._m_draft_accepted.inc(kept)
                self._spec_windows += 1
        return True

    def _accept_window(self, req: Request, drafts: List[int],
                       dlogits: List[np.ndarray], rows: np.ndarray
                       ) -> List[int]:
        """Decide one lane's verify window.  Returns the tokens to append:
        the accepted draft prefix plus exactly one more — the corrected token
        on the first rejection, or the bonus token when every draft survived.
        ``rows[j]`` is the full model's logits after window token ``j``
        (j = 0 is the pending token), the distribution plain decode would
        have sampled token ``len(generated) + j`` from."""
        out: List[int] = []
        for j, x in enumerate(drafts):
            t_idx = len(req.generated) + j  # generated index of the candidate
            if req.temperature <= 0:
                tgt = int(np.argmax(rows[j]))
                if x != tgt:
                    out.append(tgt)         # greedy correction == plain token
                    return out
                out.append(x)
                continue
            p = nucleus_probs(rows[j], req.temperature, req.top_p)
            q = nucleus_probs(dlogits[j], req.temperature, req.top_p)
            if not speculative_accept(
                    x, p, q, _spec_uniform(req.seed, t_idx, _ACCEPT_SALT)):
                out.append(residual_sample(
                    p, q, _spec_uniform(req.seed, t_idx, _RESID_SALT)))
                return out
            out.append(x)
        # every draft accepted → bonus token from the final verify row, drawn
        # exactly as plain decode would (same count-folded PRNG)
        j = len(drafts)
        out.append(self._sample_one(req, rows[j], len(req.generated) + j))
        return out

    # -- drive to completion ------------------------------------------------
    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> ServeReport:
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while True:
            s0 = time.perf_counter()
            before = self._measured_phase_ms()
            alive = self.step()
            dt_ms = (time.perf_counter() - s0) * 1e3
            self._step_wall_ms_total += dt_ms
            # residual host time this step (admission, growth bookkeeping,
            # packing) — keeps Σ phase_ms == step_wall_ms_total
            other = dt_ms - (self._measured_phase_ms() - before)
            self._phase_ms["other"] += max(0.0, other)
            self._m_phase["other"].inc(max(0.0, other))
            if not alive:
                break
            steps += 1
            if steps > max_steps:
                raise RuntimeError(self._stuck_report(max_steps))
        return self.report(time.perf_counter() - t0)

    def report(self, wall_s: float) -> ServeReport:
        fin = self.finished
        decoded = sum(len(r.generated) for r in fin)
        prefill_toks = sum(len(r.prompt) for r in fin)
        ttft_steps = [r.first_token_step - r.arrival for r in fin]
        ttft_ms = [(r.first_token_wall - r.submit_wall) * 1e3 for r in fin]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        hw = self.pool.allocator.high_water
        return ServeReport(
            completed=len(fin), decode_steps=len(self._step_wall_ms),
            prefill_tokens=prefill_toks, prefill_chunks=self.prefill_chunks,
            decoded_tokens=decoded,
            wall_s=wall_s, tok_per_s=decoded / max(wall_s, 1e-9),
            ttft_steps_mean=float(np.mean(ttft_steps)) if ttft_steps else 0.0,
            ttft_steps_by_bucket=ttft_by_prompt_bucket(fin),
            ttft_wall_p50_ms=pct(ttft_ms, 50), ttft_wall_p95_ms=pct(ttft_ms, 95),
            step_ms_p50=pct(self._step_wall_ms, 50),
            step_ms_p95=pct(self._step_wall_ms, 95),
            peak_slots=self.peak_slots, pool_high_water_blocks=hw,
            pool_block_size=self.scfg.block_size,
            pool_dtype=str(self.pool.dtype),
            pool_bytes_per_token=self._pool_bpt,
            pool_allocated_bytes_peak=hw * self.scfg.block_size
            * self._pool_bpt,
            naive_blocks=self.naive_blocks,
            block_reuse_ratio=self.naive_blocks / max(hw, 1),
            admission=self.scfg.admission,
            preemptions=self.bm.preemptions,
            preempted_requests=sum(1 for r in fin if r.preempted_at),
            swap_outs=self.bm.swap_outs, swap_ins=self.bm.swap_ins,
            swapped_bytes=self.bm.swapped_bytes,
            mean_occupancy=(float(np.mean(self._occupancy))
                            if self._occupancy else 0.0),
            mean_occupancy_retained=(float(np.mean(self._occupancy_retained))
                                     if self._occupancy_retained else 0.0),
            sparse_topk=self.scfg.sparse_topk_blocks,
            sparse_recent=self.scfg.sparse_recent_blocks,
            sparse_steps=self._sparse_steps,
            mean_selected_blocks=(self._sparse_selected
                                  / max(self._lane_steps, 1)
                                  if self._sparse_steps else 0.0),
            mean_candidate_blocks=(self._sparse_candidate
                                   / max(self._lane_steps, 1)
                                   if self._sparse_steps else 0.0),
            mean_prefill_batch=(self._prefill_lanes_total
                                / max(self.prefill_chunks, 1)),
            speculate_k=self.scfg.speculate_k,
            draft_rank=self.scfg.draft_rank,
            draft_forwards=self.draft_forwards,
            draft_proposed=self.draft_proposed,
            draft_accepted=self.draft_accepted,
            acceptance_rate=self.draft_accepted / max(self.draft_proposed, 1),
            mean_accepted=self.draft_accepted / max(self._spec_windows, 1),
            tokens_per_forward=(self._decode_appended
                                / max(self._lane_steps, 1)),
            acceptance_by_bucket=acceptance_by_prompt_bucket(fin),
            prefix_cache=self.bm.prefix is not None,
            prefix_cache_hits=self.bm.prefix.hits if self.bm.prefix else 0,
            prefix_cache_misses=(self.bm.prefix.misses
                                 if self.bm.prefix else 0),
            prefix_cache_hit_tokens=(self.bm.prefix.hit_tokens
                                     if self.bm.prefix else 0),
            prefix_cache_hit_rate=(
                self.bm.prefix.hit_tokens
                / max(self.bm.prefix.lookup_tokens, 1)
                if self.bm.prefix else 0.0),
            cow_copies=self.pool.cow_copies,
            blocks_retained=(self.bm.prefix.num_retained
                             if self.bm.prefix else 0),
            phase_ms=dict(self._phase_ms),
            step_wall_ms_total=self._step_wall_ms_total,
            trace_events=self.trace.emitted if self.trace.enabled else 0,
            trace_dropped=self.trace.dropped if self.trace.enabled else 0)


def generate_paged(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
                   max_new_tokens: int, scfg: Optional[SchedulerConfig] = None
                   ) -> Tuple[np.ndarray, ServeReport]:
    """Paged-pool twin of ``generate`` (same greedy semantics, same output
    shape) — the parity surface for scheduler tests."""
    B, Sp = prompts.shape
    scfg = scfg or SchedulerConfig(
        max_slots=B, max_new_tokens=max_new_tokens,
        max_len=Sp + max_new_tokens + 1,
        num_blocks=2 * B * (-(-(Sp + max_new_tokens) // 16)), block_size=16)
    sched = Scheduler(params, buffers, cfg, scfg)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i]),
                    max_new_tokens=max_new_tokens) for i in range(B)]
    report = sched.run(reqs)
    out = np.zeros((B, max_new_tokens), np.int32)
    for r in sched.finished:
        out[r.uid, :len(r.generated)] = r.generated
    return out, report
