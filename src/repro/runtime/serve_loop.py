"""Serving runtime over the compressed EliteKV cache (see docs/serving.md).

Two tiers:

* ``generate`` — lockstep batched greedy decoding with a contiguous cache
  (examples / parity oracle).
* ``Scheduler`` — continuous batching over the block-paged pool
  (``core.cache.PagedKVPool``): requests queue with arrival times, get
  admitted into free *slots* mid-flight, prefill their prompts in fixed-size
  token **chunks** interleaved with decode steps (so a long arriving prompt
  never stalls resident sequences), and retire on EOS or token budget — their
  blocks recycle immediately.  Each scheduler step spends at most
  ``prefill_chunk_tokens`` prompt tokens on chunked prefill before running
  one decode step over all ``max_slots`` lanes (idle and still-prefilling
  lanes are masked by length 0); with ``prefill_chunk_tokens=0`` the whole
  prompt is prefilled at admission in one call (PR-2 behaviour).  The run
  compiles once per prompt-length bucket (one-shot), once for the fixed
  chunk shape (chunked), plus once for decode.

Decoding samples per request: temperature / nucleus (top-p) with a
per-request PRNG seed, applied batched over all lanes in one jitted call;
``temperature=0`` lanes reduce exactly to greedy argmax.

Admission reserves *watermark* capacity (worst-case remaining blocks of every
resident sequence) so a decode step can never run out of pool blocks
mid-flight; physical blocks are still allocated on demand, one at a time, so
peak usage stays far below the sum of per-request worst cases whenever
arrivals stagger or sequences stop early.  Preemption/swap-out is a ROADMAP
item.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import OutOfBlocks, PagedKVPool
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, mesh=None, constrain=None,
                      moe_impl: str = "ragged", data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def prefill_step(params, buffers, batch, cache):
        return lm.apply_prefill(params, buffers, cfg, batch, cache,
                                moe_impl=moe_impl, mesh=mesh,
                                constrain=constrain, data_axes=data_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, constrain=None,
                     moe_impl: str = "ragged", greedy: bool = True,
                     data_axes=("data",)):
    constrain = constrain or (lambda n, x: x)

    def decode_step(params, buffers, tokens, cache):
        batch = ({"tokens": tokens} if cfg.frontend != "audio"
                 else {"frames": tokens})
        logits, cache = lm.apply_decode(params, buffers, cfg, batch, cache,
                                        moe_impl=moe_impl, mesh=mesh,
                                        constrain=constrain, data_axes=data_axes)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step


@dataclasses.dataclass
class ServeStats:
    """Counters for the lockstep ``generate`` path.

    ``prefill_tokens``  — prompt tokens pushed through the prefill forward
                          (batch × prompt length).
    ``decoded_tokens``  — tokens produced by decode steps (batch × new tokens).
    ``cache_bytes``     — measured bytes of the attention KV cache actually
                          allocated for the run (the paper's headline
                          compression shows up here).
    """
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_bytes: int = 0


def generate(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
             max_new_tokens: int, mesh=None, moe_impl: str = "ragged",
             cache_dtype=jnp.float32) -> Tuple[np.ndarray, ServeStats]:
    """Greedy generation for a batch of fixed-length prompts (examples/tests).

    prompts: [B, S_prompt] int32 → generated [B, max_new_tokens].
    """
    B, Sp = prompts.shape
    max_len = Sp + max_new_tokens
    cache = lm.init_cache(cfg, B, max_len, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, moe_impl=moe_impl))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh, moe_impl=moe_impl))
    logits, cache = prefill(params, buffers, {"tokens": prompts}, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    outs = [nxt]
    for _ in range(max_new_tokens - 1):
        nxt, _, cache = decode(params, buffers, nxt[:, None], cache)
        outs.append(nxt)
    from repro.core.cache import measured_cache_bytes
    stats = ServeStats(prefill_tokens=B * Sp, decoded_tokens=B * max_new_tokens,
                       cache_bytes=measured_cache_bytes(cache, B, max_len)["attn_bytes"])
    return np.stack([np.asarray(o) for o in outs], axis=1), stats


# ---------------------------------------------------------------------------
# continuous batching over the paged pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler steps (the
    simulated clock) — the Poisson driver maps wall arrival times onto it.

    Sampling is per request: ``temperature <= 0`` is greedy argmax; otherwise
    nucleus sampling from the smallest token set whose probability mass
    reaches ``top_p``, driven by a PRNG keyed on ``seed`` and folded with the
    token index — the same (seed, prompt) always yields the same tokens.
    """
    uid: int
    prompt: np.ndarray                    # [Sp] int32
    max_new_tokens: int
    arrival: float = 0.0
    temperature: float = 0.0              # 0 → greedy
    top_p: float = 1.0                    # nucleus mass (1 → full softmax)
    seed: int = 0                         # per-request PRNG seed
    # filled in by the scheduler:
    generated: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0                  # prompt tokens already in the pool
    submit_wall: float = 0.0
    first_token_wall: float = 0.0
    first_token_step: int = -1
    finish_step: int = -1
    finish_reason: str = ""               # "eos" | "budget"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 4                    # concurrent sequences per decode step
    block_size: int = 16                  # tokens per pool block
    num_blocks: int = 128                 # pool capacity
    max_new_tokens: int = 64              # hard per-request generation cap
    max_len: int = 256                    # per-sequence token cap (table width)
    eos_id: Optional[int] = None
    prefill_bucket: int = 16              # prompts pad up to a multiple of this
    prefill_chunk_tokens: int = 0         # per-step prefill token budget
                                          # (0 → whole prompt at admission)
    use_kernel: bool = True               # Pallas paged kernel on TPU
    cache_dtype: Any = jnp.float32

    @property
    def max_blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)


def sample_tokens(logits, temps, top_ps, seeds, counts):
    """Batched per-request sampling for one decode step.

    logits [B,V] fp32-castable, temps/top_ps [B] fp32, seeds/counts [B] int32.
    Lane ``i`` draws from PRNG ``fold_in(PRNGKey(seeds[i]), counts[i])`` — the
    count is the request's token index, so replaying a request with the same
    seed reproduces its tokens regardless of which slot/step served it.
    ``temps[i] <= 0`` reduces exactly to greedy argmax.  → [B] int32.
    """

    def one(lg, temp, top_p, seed, count):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
        greedy = jnp.argmax(lg).astype(jnp.int32)
        scaled = lg.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
        order = jnp.argsort(-scaled)                # descending
        sl = scaled[order]
        probs = jax.nn.softmax(sl)
        # nucleus: drop tokens whose preceding cumulative mass already covers
        # top_p (the smallest covering set always keeps its first member)
        cut = (jnp.cumsum(probs) - probs) >= top_p
        sl = jnp.where(cut, -jnp.inf, sl)
        tok = order[jax.random.categorical(key, sl)].astype(jnp.int32)
        return jnp.where(temp <= 0.0, greedy, tok)

    return jax.vmap(one)(logits, temps, top_ps, seeds, counts)


def ttft_by_prompt_bucket(finished: List[Request],
                          edges: Tuple[int, ...] = (16, 64)) -> Dict[str, float]:
    """Mean TTFT (scheduler steps from arrival to first token) per prompt-
    length bucket — the quantity chunked prefill improves for *short* prompts
    that would otherwise queue behind long ones.  ``edges`` split lengths into
    len(edges)+1 buckets: <=16, 17..64, >64 by default."""
    out: Dict[str, float] = {}
    lo = 0
    for hi in tuple(edges) + (None,):
        label = (f"{lo + 1}-{hi}" if hi is not None else f">{lo}")
        ttfts = [r.first_token_step - r.arrival for r in finished
                 if lo < len(r.prompt) and (hi is None or len(r.prompt) <= hi)]
        if ttfts:
            out[label] = float(np.mean(ttfts))
        lo = hi if hi is not None else lo
    return out


@dataclasses.dataclass
class ServeReport:
    """End-of-run scheduler metrics (docs/serving.md explains how to read
    them).  TTFT = arrival → first token; ``_steps`` is in simulated
    scheduler steps, ``_wall`` in wall milliseconds."""
    completed: int = 0
    decode_steps: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0               # prefill forward calls issued
    decoded_tokens: int = 0
    wall_s: float = 0.0
    tok_per_s: float = 0.0
    ttft_steps_mean: float = 0.0
    ttft_steps_by_bucket: Dict[str, float] = dataclasses.field(default_factory=dict)
    ttft_wall_p50_ms: float = 0.0
    ttft_wall_p95_ms: float = 0.0
    step_ms_p50: float = 0.0
    step_ms_p95: float = 0.0
    peak_slots: int = 0
    pool_high_water_blocks: int = 0
    pool_block_size: int = 0
    naive_blocks: int = 0                 # Σ per-request worst-case blocks
    block_reuse_ratio: float = 0.0        # naive / high-water (>1 ⇒ paging won)

    def summary(self) -> str:
        bucket = "".join(f" ttft[{k}]={v:.1f}" for k, v in
                         self.ttft_steps_by_bucket.items())
        return (f"completed={self.completed} steps={self.decode_steps} "
                f"decoded={self.decoded_tokens} tok/s={self.tok_per_s:.1f} "
                f"ttft_steps={self.ttft_steps_mean:.1f}{bucket} "
                f"ttft_ms p50/p95={self.ttft_wall_p50_ms:.0f}/{self.ttft_wall_p95_ms:.0f} "
                f"step_ms p50/p95={self.step_ms_p50:.1f}/{self.step_ms_p95:.1f} "
                f"peak_slots={self.peak_slots} "
                f"blocks high-water/naive={self.pool_high_water_blocks}/"
                f"{self.naive_blocks} reuse×{self.block_reuse_ratio:.2f}")


class Scheduler:
    """Continuous-batching serving loop over the paged compressed cache."""

    def __init__(self, params, buffers, cfg: ModelConfig,
                 scfg: SchedulerConfig, mesh=None, moe_impl: str = "ragged"):
        assert cfg.elitekv.enabled, "paged serving requires an EliteKV config"
        self.params, self.buffers, self.cfg, self.scfg = params, buffers, cfg, scfg
        self.pool = PagedKVPool(cfg, scfg.num_blocks, scfg.block_size,
                                dtype=scfg.cache_dtype)
        self.slots: List[Optional[Request]] = [None] * scfg.max_slots
        self.waiting: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self.t = 0                          # simulated clock (decode steps)
        self._reserved_blocks = 0           # watermark: worst-case growth of residents
        self._step_wall_ms: List[float] = []
        self.peak_slots = 0
        self.naive_blocks = 0
        self.prefill_chunks = 0             # prefill forward calls issued

        def _prefill(params, buffers, tokens, pages, slot_mapping):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping, moe_impl=moe_impl,
                                          mesh=mesh)

        def _prefill_resume(params, buffers, tokens, pages, slot_mapping,
                            chunk_start, block_tables, prefix_lens):
            return lm.apply_prefill_paged(params, buffers, cfg,
                                          {"tokens": tokens}, pages,
                                          slot_mapping,
                                          chunk_start=chunk_start,
                                          block_tables=block_tables,
                                          prefix_lens=prefix_lens,
                                          block_size=scfg.block_size,
                                          moe_impl=moe_impl, mesh=mesh)

        def _decode(params, buffers, tokens, pages, slot_mapping,
                    block_tables, lengths):
            return lm.apply_decode_paged(params, buffers, cfg,
                                         {"tokens": tokens}, pages,
                                         slot_mapping, block_tables, lengths,
                                         block_size=scfg.block_size,
                                         use_kernel=scfg.use_kernel,
                                         moe_impl=moe_impl, mesh=mesh)

        # donate the pages so XLA updates the pool in place rather than
        # copying every block each step (donation is unsupported + noisy on CPU)
        donate = () if jax.default_backend() == "cpu" else (3,)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._prefill_resume = jax.jit(_prefill_resume, donate_argnums=donate)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._sample = jax.jit(sample_tokens)

    # -- request intake -----------------------------------------------------
    def submit(self, req: Request) -> None:
        req.max_new_tokens = min(req.max_new_tokens, self.scfg.max_new_tokens)
        assert len(req.prompt) + req.max_new_tokens <= self.scfg.max_len, \
            (len(req.prompt), req.max_new_tokens, self.scfg.max_len)
        if self._worst_case_blocks(req) > self.scfg.num_blocks:
            raise OutOfBlocks(
                f"request {req.uid} needs {self._worst_case_blocks(req)} blocks "
                f"worst-case but the pool only has {self.scfg.num_blocks} — "
                f"it could never be admitted")
        req.submit_wall = time.perf_counter()
        self.waiting.append(req)
        self.naive_blocks += self._worst_case_blocks(req)

    def _worst_case_blocks(self, req: Request) -> int:
        return -(-(len(req.prompt) + req.max_new_tokens) // self.scfg.block_size)

    def _recompute_reserved(self) -> None:
        """Watermark: worst-case blocks still owed to resident sequences.
        Admission against ``num_free - reserved`` guarantees decode can always
        grow every resident by its full budget — no mid-flight OutOfBlocks."""
        self._reserved_blocks = sum(
            max(0, self._worst_case_blocks(s) - len(self.pool.block_table(s.uid)))
            for s in self.slots if s is not None)

    # -- admission ----------------------------------------------------------
    def _try_admit(self) -> int:
        admitted = 0
        self._recompute_reserved()
        while self.waiting and self.waiting[0].arrival <= self.t:
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                break
            req = self.waiting[0]
            need = self._worst_case_blocks(req)
            if self.pool.allocator.num_free - self._reserved_blocks < need:
                break                       # pool watermark exhausted — wait
            self.waiting.popleft()
            self._admit(slot, req)
            self._recompute_reserved()
            admitted += 1
        return admitted

    def _admit(self, slot: int, req: Request) -> None:
        """Claim a slot and the prompt's pool blocks; prefill itself happens
        in ``_prefill_work`` (chunked, interleaved with decode steps)."""
        self.pool.ensure_capacity(req.uid, len(req.prompt))
        req.prefill_pos = 0
        self.slots[slot] = req

    # -- chunked prefill ----------------------------------------------------
    def _run_chunk(self, req: Request, start: int, n: int, pad: int):
        """One prefill forward over prompt[start:start+n], padded to ``pad``.
        Chunk 0 is a fresh causal prefill; resumed chunks additionally attend
        to the cached prefix through the block table."""
        tokens = np.zeros((1, pad), np.int32)
        tokens[0, :n] = req.prompt[start:start + n]
        sm = self.pool.prefill_slot_mapping(req.uid, start, n, pad)[None]
        if start == 0:
            logits, self.pool.pages = self._prefill(
                self.params, self.buffers, jnp.asarray(tokens),
                self.pool.pages, jnp.asarray(sm))
        else:
            bt = self.pool.block_table_array([req.uid],
                                             self.scfg.max_blocks_per_seq)
            logits, self.pool.pages = self._prefill_resume(
                self.params, self.buffers, jnp.asarray(tokens),
                self.pool.pages, jnp.asarray(sm),
                jnp.asarray(start, jnp.int32), jnp.asarray(bt),
                jnp.asarray([start], jnp.int32))
        req.prefill_pos = start + n
        self.prefill_chunks += 1
        return logits

    def _prefill_work(self) -> None:
        """Spend this step's prefill token budget on mid-prefill slots, FCFS
        by arrival.  ``prefill_chunk_tokens == 0`` means no budget cap: every
        newly admitted prompt prefills whole in one call (one-shot mode)."""
        chunk = self.scfg.prefill_chunk_tokens
        left = chunk if chunk > 0 else None
        while left is None or left > 0:
            cand = [(s.arrival, i) for i, s in enumerate(self.slots)
                    if s is not None and s.prefill_pos < len(s.prompt)]
            if not cand:
                return
            _, slot = min(cand)
            req = self.slots[slot]
            sp = len(req.prompt)
            start = req.prefill_pos
            if left is None:                # one-shot: whole (padded) prompt
                n = sp - start
                pad = -(-sp // self.scfg.prefill_bucket) * self.scfg.prefill_bucket
            else:                           # fixed chunk shape → one compile
                n = min(chunk, sp - start, left)
                pad = chunk
                left -= n
            logits = self._run_chunk(req, start, n, pad)
            if req.prefill_pos >= sp:       # final chunk → sample first token
                if req.temperature > 0:
                    first = int(np.asarray(self._sample(
                        logits[:, n - 1],
                        jnp.asarray([req.temperature], jnp.float32),
                        jnp.asarray([req.top_p], jnp.float32),
                        jnp.asarray([req.seed], jnp.int32),
                        jnp.asarray([0], jnp.int32)))[0])
                else:
                    first = int(jnp.argmax(logits[0, n - 1]))
                req.generated.append(first)
                req.first_token_wall = time.perf_counter()
                req.first_token_step = self.t
                self._maybe_finish(slot, first)

    # -- retirement ---------------------------------------------------------
    def _maybe_finish(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        if self.scfg.eos_id is not None and token == self.scfg.eos_id:
            req.finish_reason = "eos"
        elif len(req.generated) >= req.max_new_tokens:
            req.finish_reason = "budget"
        else:
            return
        req.finish_step = self.t
        self.pool.free_seq(req.uid)         # blocks recycle immediately
        self.finished.append(req)
        self.slots[slot] = None

    # -- one scheduler iteration -------------------------------------------
    def step(self) -> bool:
        """Admit + chunk-prefill + decode once.  Returns False when drained."""
        self._try_admit()
        self._prefill_work()
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        self.peak_slots = max(self.peak_slots, len(occupied))
        # decode lanes: slots whose prompt is fully in the pool (mid-prefill
        # slots sit out this decode step — their lane is masked by length 0)
        active = [i for i in occupied
                  if self.slots[i].prefill_pos >= len(self.slots[i].prompt)]
        if not active:
            if not occupied and not self.waiting:
                return False
            self.t += 1                     # waiting on arrivals or prefill
            return True

        scfg = self.scfg
        B = scfg.max_slots
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ps = np.ones((B,), np.float32)
        seeds = np.zeros((B,), np.int32)
        counts = np.zeros((B,), np.int32)
        seq_ids: List[Optional[int]] = [None] * B
        positions = [0] * B
        for i in active:
            req = self.slots[i]
            cur = self.pool.length(req.uid)
            self.pool.ensure_capacity(req.uid, cur + 1)   # may grow one block
            tokens[i, 0] = req.generated[-1]
            lengths[i] = cur + 1
            seq_ids[i] = req.uid
            positions[i] = cur
            temps[i] = req.temperature
            top_ps[i] = req.top_p
            seeds[i] = req.seed
            counts[i] = len(req.generated)  # token index within the request
        sm = self.pool.slot_mapping(seq_ids, positions)
        bt = self.pool.block_table_array(seq_ids, scfg.max_blocks_per_seq)

        t0 = time.perf_counter()
        logits, self.pool.pages = self._decode(self.params, self.buffers,
                                               jnp.asarray(tokens),
                                               self.pool.pages,
                                               jnp.asarray(sm), jnp.asarray(bt),
                                               jnp.asarray(lengths))
        if np.any(temps > 0):
            nxt = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(temps),
                                          jnp.asarray(top_ps),
                                          jnp.asarray(seeds),
                                          jnp.asarray(counts)))
        else:                               # all-greedy step: skip the
            nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))  # sampler
        self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)
        self.t += 1
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self._maybe_finish(i, tok)
        return bool(self.waiting) or any(s is not None for s in self.slots)

    # -- drive to completion ------------------------------------------------
    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> ServeReport:
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"scheduler did not drain in {max_steps} steps")
        return self.report(time.perf_counter() - t0)

    def report(self, wall_s: float) -> ServeReport:
        fin = self.finished
        decoded = sum(len(r.generated) for r in fin)
        prefill_toks = sum(len(r.prompt) for r in fin)
        ttft_steps = [r.first_token_step - r.arrival for r in fin]
        ttft_ms = [(r.first_token_wall - r.submit_wall) * 1e3 for r in fin]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        hw = self.pool.allocator.high_water
        return ServeReport(
            completed=len(fin), decode_steps=len(self._step_wall_ms),
            prefill_tokens=prefill_toks, prefill_chunks=self.prefill_chunks,
            decoded_tokens=decoded,
            wall_s=wall_s, tok_per_s=decoded / max(wall_s, 1e-9),
            ttft_steps_mean=float(np.mean(ttft_steps)) if ttft_steps else 0.0,
            ttft_steps_by_bucket=ttft_by_prompt_bucket(fin),
            ttft_wall_p50_ms=pct(ttft_ms, 50), ttft_wall_p95_ms=pct(ttft_ms, 95),
            step_ms_p50=pct(self._step_wall_ms, 50),
            step_ms_p95=pct(self._step_wall_ms, 95),
            peak_slots=self.peak_slots, pool_high_water_blocks=hw,
            pool_block_size=self.scfg.block_size,
            naive_blocks=self.naive_blocks,
            block_reuse_ratio=self.naive_blocks / max(hw, 1))


def generate_paged(params, buffers, cfg: ModelConfig, prompts: jnp.ndarray,
                   max_new_tokens: int, scfg: Optional[SchedulerConfig] = None
                   ) -> Tuple[np.ndarray, ServeReport]:
    """Paged-pool twin of ``generate`` (same greedy semantics, same output
    shape) — the parity surface for scheduler tests."""
    B, Sp = prompts.shape
    scfg = scfg or SchedulerConfig(
        max_slots=B, max_new_tokens=max_new_tokens,
        max_len=Sp + max_new_tokens + 1,
        num_blocks=2 * B * (-(-(Sp + max_new_tokens) // 16)), block_size=16)
    sched = Scheduler(params, buffers, cfg, scfg)
    reqs = [Request(uid=i, prompt=np.asarray(prompts[i]),
                    max_new_tokens=max_new_tokens) for i in range(B)]
    report = sched.run(reqs)
    out = np.zeros((B, max_new_tokens), np.int32)
    for r in sched.finished:
        out[r.uid, :len(r.generated)] = r.generated
    return out, report
