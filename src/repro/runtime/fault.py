"""Fault tolerance & straggler mitigation for the training runtime.

Mechanisms (single-process simulations of the multi-host patterns — the
abstractions are the deliverable, exercised by tests/test_fault.py):

  * ``FaultTolerantRunner`` — supervises a train loop; on failure (injected
    or real) it restarts from the last committed checkpoint.  Restart count,
    re-trained steps, and data-stream determinism are all observable.
  * ``HeartbeatMonitor`` — per-"host" heartbeat ages; hosts silent past the
    deadline are declared dead → triggers restart with survivors (elastic).
  * ``StragglerPolicy`` — tracks per-step/host durations; hosts persistently
    slower than ``threshold × median`` are flagged for eviction (at real
    scale this drives the re-mesh; here it feeds HeartbeatMonitor).
  * elastic re-mesh — ``repro.checkpoint`` stores unsharded leaves, so a
    restart may resume on a different device count; see
    ``runtime/elastic.py.reshard``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by fault-injection hooks (tests / chaos drills)."""


@dataclasses.dataclass
class HostState:
    last_beat: float
    durations: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: int, deadline_s: float = 60.0, clock=time.monotonic):
        self.clock = clock
        self.deadline = deadline_s
        self.hosts: Dict[int, HostState] = {
            h: HostState(last_beat=clock()) for h in range(hosts)}

    def beat(self, host: int, duration_s: Optional[float] = None):
        st = self.hosts[host]
        st.last_beat = self.clock()
        if duration_s is not None:
            st.durations.append(duration_s)

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if st.alive and now - st.last_beat > self.deadline]

    def evict(self, host: int):
        self.hosts[host].alive = False

    @property
    def alive_hosts(self) -> List[int]:
        return [h for h, st in self.hosts.items() if st.alive]


class StragglerPolicy:
    """Flag hosts persistently slower than threshold × median step time."""

    def __init__(self, threshold: float = 1.5, window: int = 20, min_obs: int = 5):
        self.threshold = threshold
        self.window = window
        self.min_obs = min_obs

    def stragglers(self, monitor: HeartbeatMonitor) -> List[int]:
        recents = {h: st.durations[-self.window:]
                   for h, st in monitor.hosts.items() if st.alive}
        meds = {h: np.median(d) for h, d in recents.items() if len(d) >= self.min_obs}
        if len(meds) < 2:
            return []
        global_med = float(np.median(list(meds.values())))
        return [h for h, m in meds.items() if m > self.threshold * global_med]


class FaultTolerantRunner:
    """Run step_fn for num_steps with checkpoint/restart supervision.

    ``step_fn(state, step) -> state`` may raise; ``save_fn(state, step)``
    commits; ``restore_fn() -> (state, step) | None`` reloads.  Failures
    bounded by ``max_restarts``.
    """

    def __init__(self, step_fn, save_fn, restore_fn, ckpt_every: int,
                 max_restarts: int = 10,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.fault_hook = fault_hook
        self.restarts = 0
        self.steps_replayed = 0

    def run(self, init_state, num_steps: int):
        state, start = init_state, 0
        restored = self.restore_fn()
        if restored is not None:
            state, start = restored
        step = start
        while step < num_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state = self.step_fn(state, step)
                step += 1
                if self.ckpt_every and step % self.ckpt_every == 0:
                    self.save_fn(state, step)
            except InjectedFault:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored = self.restore_fn()
                if restored is None:
                    state, step = init_state, 0
                else:
                    state, new_step = restored
                    self.steps_replayed += step - new_step
                    step = new_step
        return state, step
