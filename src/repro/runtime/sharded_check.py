"""Subprocess worker for multi-device serving checks.

The conftest pins the test process to ONE CPU device (determinism), so every
multi-device check — tests/test_sharded_serving.py, the benchmark scaling
rows, and the CI ``sharded`` job — runs this module in a fresh subprocess
that forces its own host-device count *before* importing jax:

    PYTHONPATH=src python -m repro.runtime.sharded_check \
        --devices 8 --tp 2 --dp 2 --scenarios plain,recompute,prefix,int8,spec

It serves a fixed deterministic request set (greedy, seeded) through each
scenario on a tiny 2-layer EliteKV model and prints ONE JSON object on
stdout: per-scenario ``{uid: tokens}`` streams plus report fields (tok/s,
ttft percentiles, per-replica occupancy, pool bytes per device).  The caller
compares token streams across (tp, dp) settings — the sharded serving path
(kernels/ops.py TP wrappers + runtime/router.py) is bit-identical to
single-device, so ``tokens`` must match EXACTLY, not approximately.

``--parity`` instead checks the shard_map decode/verify epilogue directly
against the single-device kernels on random operands (bitwise equality),
covering f32 and int8 pages at every tp that divides the head count.

Scenario knobs mirror launch/serve.py flags: ``plain`` (chunked prefill +
swap eviction under pool pressure), ``recompute`` (same, recompute
eviction), ``prefix`` (content-addressed prefix cache + shared prompt
prefix), ``int8`` (quantized pool), ``spec`` (self-speculative decode).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# SchedulerConfig overrides per scenario; "shared" is the shared-prompt-prefix
# length (request-builder knob, not a SchedulerConfig field).
SCENARIOS = {
    "plain": dict(eviction="swap"),
    "recompute": dict(eviction="recompute"),
    "prefix": dict(prefix_cache=True, shared=16),
    "int8": dict(cache_dtype="int8"),
    "spec": dict(speculate_k=2),
}
N_REQUESTS = 6
NEW_TOKENS = 8


def _build_requests(serve_loop, prompts, shared: int = 0):
    """Fresh Request objects every call — ``generated`` is mutable, so
    reusing requests across runs would leak one run's tokens into the next."""
    pre = list(range(1, 1 + shared))
    return [serve_loop.Request(uid=i, prompt=pre + prompts[i],
                               max_new_tokens=NEW_TOKENS, arrival=i // 2,
                               temperature=0.0, top_p=1.0, seed=100 + i)
            for i in range(N_REQUESTS)]


def _run_scenario(name, params, buffers, cfg, tp, dp, prompts):
    import jax
    from repro.launch.mesh import make_serving_mesh, replica_meshes
    from repro.runtime import serve_loop
    from repro.runtime.router import Router

    kw = dict(SCENARIOS[name])
    shared = kw.pop("shared", 0)
    scfg = serve_loop.SchedulerConfig(
        max_slots=2, block_size=8, num_blocks=24, prefill_chunk_tokens=8,
        max_new_tokens=NEW_TOKENS, **kw)
    reqs = _build_requests(serve_loop, prompts, shared=shared)
    meshes = None
    if tp > 1 or dp > 1:
        meshes = replica_meshes(make_serving_mesh(tp=tp, dp=dp))
    if dp > 1:
        router = Router(params, buffers, cfg, scfg, num_replicas=dp,
                        meshes=meshes)
        rep = router.run(reqs)
        pool0 = router.replicas[0].pool
        return {
            "tokens": {str(u): t for u, t in router.finished_tokens().items()},
            "report": {
                "completed": rep.completed,
                "tok_s": rep.tok_per_s,
                "ttft_wall_p50_ms": rep.ttft_wall_p50_ms,
                "ttft_wall_p95_ms": rep.ttft_wall_p95_ms,
                "preemptions": rep.preemptions,
                "routed": rep.routed,
                "imbalance": rep.imbalance,
                "occupancy_per_replica": [r.mean_occupancy for r in rep.replicas],
                "pool_bytes_per_token_per_device": pool0.bytes_per_token_per_device(),
            },
        }
    mesh = meshes[0] if meshes else None
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg, mesh=mesh)
    rep = sched.run(reqs)
    return {
        "tokens": {str(r.uid): list(r.generated) for r in sched.finished},
        "report": {
            "completed": rep.completed,
            "tok_s": rep.tok_per_s,
            "ttft_wall_p50_ms": rep.ttft_wall_p50_ms,
            "ttft_wall_p95_ms": rep.ttft_wall_p95_ms,
            "preemptions": rep.preemptions,
            "routed": [len(sched.finished)],
            "imbalance": 1.0,
            "occupancy_per_replica": [rep.mean_occupancy],
            "pool_bytes_per_token_per_device": sched.pool.bytes_per_token_per_device(),
        },
    }


def _run_parity():
    """Bitwise kernel-vs-oracle parity for the shard_map TP epilogue."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_serving_mesh

    rng = np.random.default_rng(0)
    B, nh, nkv, r2, d_c, bs, nb = 3, 4, 4, 8, 4, 8, 6
    G = nh // nkv
    n_slots = nb * bs
    q_e = jnp.asarray(rng.standard_normal((B, nh, r2)), jnp.float32)
    q_lat = jnp.asarray(rng.standard_normal((B, nh, d_c)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((n_slots, nkv, r2)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((n_slots, d_c)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nb, (B, 4)), jnp.int32)
    ln = jnp.asarray([5, 17, 30], jnp.int32)
    out = {}

    ref = kops.elite_decode_paged(q_e, q_lat, K, C, C, bt, ln, G, 0.5, bs,
                                  force_xla=True)
    for tp in (2, 4):
        got = kops.elite_decode_paged_tp(
            q_e, q_lat, K, C, C, None, bt, ln, G, 0.5, bs,
            mesh=make_serving_mesh(tp=tp), force_xla=True)
        out[f"decode_tp{tp}"] = bool(jnp.all(got == ref))

    W = 3
    qv_e = jnp.asarray(rng.standard_normal((B, W, nh, r2)), jnp.float32)
    qv_lat = jnp.asarray(rng.standard_normal((B, W, nh, d_c)), jnp.float32)
    qo = jnp.asarray([2, 10, 20], jnp.int32)
    refv = kops.elite_verify_paged(qv_e, qv_lat, K, C, C, bt, qo, ln, G, 0.5,
                                   bs, force_xla=True)
    gotv = kops.elite_verify_paged_tp(
        qv_e, qv_lat, K, C, C, None, bt, qo, ln, G, 0.5, bs,
        mesh=make_serving_mesh(tp=2), force_xla=True)
    out["verify_tp2"] = bool(jnp.all(gotv == refv))

    Kq = jnp.asarray(rng.integers(-127, 127, (n_slots, nkv, r2)), jnp.int8)
    Cq = jnp.asarray(rng.integers(-127, 127, (n_slots, d_c)), jnp.int8)
    ks = jnp.asarray(rng.random((n_slots,)) + 0.1, jnp.float32)
    cs = jnp.asarray(rng.random((n_slots,)) + 0.1, jnp.float32)
    refq = kops.elite_decode_paged_q8(q_e, q_lat, Kq, Cq, Cq, ks, cs, cs, bt,
                                      ln, G, 0.5, bs, force_xla=True)
    gotq = kops.elite_decode_paged_tp(
        q_e, q_lat, Kq, Cq, Cq, (ks, cs, cs), bt, ln, G, 0.5, bs,
        mesh=make_serving_mesh(tp=2), force_xla=True)
    out["decode_q8_tp2"] = bool(jnp.all(gotq == refq))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (set before jax import)")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--scenarios", default="plain",
                    help=f"comma list from {sorted(SCENARIOS)}")
    ap.add_argument("--parity", action="store_true",
                    help="run shard_map kernel-vs-oracle bitwise parity "
                         "instead of serving scenarios")
    args = ap.parse_args(argv)

    # must land before jax initialises; harmless if the parent already set it
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import dataclasses
    import numpy as np
    import jax
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.models import lm

    result = {"devices": jax.device_count(), "tp": args.tp, "dp": args.dp}
    if args.parity:
        result["parity"] = _run_parity()
        json.dump(result, sys.stdout)
        return result

    cfg = dataclasses.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=128),
        elitekv=EliteKVConfig(enabled=True, elite_r=4, d_ckv=64))
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(1, 128, 12 + i)))
               for i in range(N_REQUESTS)]
    result["scenarios"] = {
        name: _run_scenario(name, params, buffers, cfg, args.tp, args.dp,
                            prompts)
        for name in args.scenarios.split(",")}
    json.dump(result, sys.stdout)
    return result


if __name__ == "__main__":
    main()
