"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints store unsharded leaves (checkpoint/checkpointer.py), so scale-up /
scale-down is: load → ``jax.device_put`` onto the new mesh's shardings →
continue.  The data pipeline re-derives host slices from the new
(host_id, num_hosts), and the deterministic (epoch, step) stream keeps the
token order consistent across the resize.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from repro.distributed import sharding as shd


def reshard_state(params, opt_state, cfg, new_mesh, moment_dtype: str = "float32"):
    """Re-place an (unsharded or differently-sharded) state on ``new_mesh``."""
    plan = shd.plan_for_mesh(new_mesh)
    pspecs = shd.param_pspecs(params, cfg, plan)
    pshard = jax.tree.map(plan.named, pspecs,
                          is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    new_params = jax.tree.map(jax.device_put, params, pshard)
    ospecs = shd.opt_pspecs(opt_state, params, cfg, plan, moment_dtype)
    oshard = jax.tree.map(plan.named, ospecs,
                          is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    new_opt = jax.tree.map(jax.device_put, opt_state, oshard)
    return new_params, new_opt, plan
