"""Chrome trace-event JSON export of a ``Tracer``'s event stream.

The output loads directly in Perfetto (https://ui.perfetto.dev — "Open trace
file") or chrome://tracing: one process, one *thread track* per tracer track
— ``scheduler`` (phase spans), ``kernel`` (dispatch spans), ``pool`` (block
churn instants + occupancy counter), and one ``slot<i>`` row per scheduler
slot showing request residency spans.  Format reference:
https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

Timestamps are microseconds from the tracer's origin (Chrome's convention);
counter events render as Perfetto counter tracks.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Union

from repro.obs.trace import Event, Tracer

#: Fixed thread ids for the well-known tracks (stable across runs so diffs
#: of two timelines line up); other tracks (slots) get ids after these.
_PINNED_TRACKS = ("scheduler", "kernel", "pool")


def _track_order(tracks: Iterable[str]) -> List[str]:
    rest = sorted(set(tracks) - set(_PINNED_TRACKS),
                  key=lambda t: (len(t), t))   # slot2 < slot10
    return [t for t in _PINNED_TRACKS] + rest


def to_chrome_trace(events: Union[Tracer, Iterable[Event]],
                    process_name: str = "elitekv-serve",
                    pid: int = 1) -> Dict[str, Any]:
    """Convert tracer events to a Chrome trace-event JSON object (the
    ``{"traceEvents": [...]}`` envelope form)."""
    if isinstance(events, Tracer):
        events = events.events()
    events = list(events)
    tids = {t: i for i, t in enumerate(_track_order(e.track for e in events))}

    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": process_name}},
    ]
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                    "args": {"name": track}})
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_sort_index", "args": {"sort_index": tid}})

    for ev in events:
        rec: Dict[str, Any] = {
            "name": ev.name, "ph": ev.ph, "cat": ev.cat, "pid": pid,
            "tid": tids[ev.track], "ts": round(ev.ts * 1e6, 3),
        }
        if ev.ph == "X":
            rec["dur"] = round(ev.dur * 1e6, 3)
        if ev.ph == "i":
            rec["s"] = "t"                   # thread-scoped instant
        if ev.args:
            rec["args"] = ev.args_dict()
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path, events: Union[Tracer, Iterable[Event]],
                       process_name: str = "elitekv-serve") -> Path:
    """Serialize to ``path``; returns the path written."""
    path = Path(path)
    trace = to_chrome_trace(events, process_name=process_name)
    path.write_text(json.dumps(trace, default=_json_default), encoding="utf-8")
    return path


def _json_default(obj: Any) -> Any:
    """Event args may carry numpy scalars / arrays — coerce rather than fail
    (observability must never crash the run it is observing)."""
    if hasattr(obj, "item"):
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)
