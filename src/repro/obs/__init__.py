"""Serving observability layer (docs/observability.md).

Three independent pieces, stdlib-only so every layer of the stack can
depend on them without import cycles:

* ``trace``    — a low-overhead ring-buffer event tracer (spans, instants,
                 counters) the scheduler, block pool and kernel wrappers
                 emit structured events into.
* ``metrics``  — a process-wide registry of counters / gauges / histograms
                 with Prometheus text-format and JSON export.
* ``timeline`` — export of the event stream as Chrome trace-event JSON,
                 viewable in Perfetto (https://ui.perfetto.dev), one track
                 per pool slot plus scheduler / pool / kernel tracks.
"""
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import NULL_TRACER, Event, Tracer
from repro.obs.timeline import to_chrome_trace, write_chrome_trace

__all__ = ["Event", "Tracer", "NULL_TRACER", "MetricsRegistry", "REGISTRY",
           "to_chrome_trace", "write_chrome_trace"]
