"""Low-overhead ring-buffer event tracer (docs/observability.md).

The tracer records structured ``Event`` records into a bounded deque — a
fixed-capacity *ring buffer*, so a long serving run keeps the most recent
window of events instead of growing without bound.  Emission is a time read
plus a tuple append on the host; it never touches JAX, PRNG state, or the
scheduler's decisions, so a traced run produces bit-identical tokens to an
untraced one (regression-tested in tests/test_obs.py).

Event phases mirror the Chrome trace-event format the timeline exporter
targets:

* ``X`` — a *complete span* with a duration (``Tracer.span`` context manager)
* ``B`` / ``E`` — begin/end of a long-lived span (request residency in a slot)
* ``i`` — an instant event (submit, admit, alloc, free, preempt, …)
* ``C`` — a counter sample (pool blocks in use, occupied slots)

Every event carries a ``track`` — the timeline row it renders on:
``"scheduler"`` (phase spans), ``"pool"`` (block churn), ``"kernel"``
(opt-in dispatch spans), and ``"slot<i>"`` (per-slot request lifecycles).

Disabled tracers (``Tracer(enabled=False)`` or the shared ``NULL_TRACER``)
reduce every emit to one attribute check, so instrumented code paths need no
``if tracer:`` guards.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Event:
    """One trace record.  ``ts``/``dur`` are seconds relative to the
    tracer's origin (monotonic ``perf_counter`` clock)."""
    name: str
    ph: str                      # "X" | "B" | "E" | "i" | "C"
    ts: float
    track: str = "scheduler"
    cat: str = "event"
    dur: float = 0.0             # "X" only
    args: Tuple[Tuple[str, Any], ...] = ()

    def arg(self, key: str, default: Any = None) -> Any:
        for k, v in self.args:
            if k == key:
                return v
        return default

    def args_dict(self) -> Dict[str, Any]:
        return dict(self.args)


class Tracer:
    """Bounded event recorder.  ``capacity`` is the ring size in events —
    older events are dropped once full (``dropped`` counts them), which
    bounds memory for arbitrarily long runs while keeping the recent window
    the stuck-scheduler diagnostics and the timeline export need."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self.origin = time.perf_counter()
        self.emitted = 0                    # lifetime emits (≥ len(events()))

    # -- clock --------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self.origin

    # -- emission -----------------------------------------------------------
    def _emit(self, ev: Event) -> None:
        self._buf.append(ev)
        self.emitted += 1

    def instant(self, name: str, track: str = "scheduler",
                cat: str = "event", **args: Any) -> None:
        if not self.enabled:
            return
        self._emit(Event(name, "i", self.now(), track, cat,
                         args=tuple(args.items())))

    def counter(self, name: str, value: float, track: str = "scheduler",
                cat: str = "counter") -> None:
        if not self.enabled:
            return
        self._emit(Event(name, "C", self.now(), track, cat,
                         args=(("value", value),)))

    def begin(self, name: str, track: str = "scheduler",
              cat: str = "event", **args: Any) -> None:
        if not self.enabled:
            return
        self._emit(Event(name, "B", self.now(), track, cat,
                         args=tuple(args.items())))

    def end(self, name: str, track: str = "scheduler",
            cat: str = "event", **args: Any) -> None:
        if not self.enabled:
            return
        self._emit(Event(name, "E", self.now(), track, cat,
                         args=tuple(args.items())))

    @contextlib.contextmanager
    def span(self, name: str, track: str = "scheduler", cat: str = "span",
             **args: Any) -> Iterator[None]:
        """Time a block as one complete ("X") event.  The event is appended
        at *exit* (Chrome's complete-event convention: ``ts`` start + ``dur``),
        so a span that raises still records its duration."""
        if not self.enabled:
            yield
            return
        t0 = self.now()
        try:
            yield
        finally:
            self._emit(Event(name, "X", t0, track, cat,
                             dur=self.now() - t0, args=tuple(args.items())))

    # -- introspection ------------------------------------------------------
    @property
    def dropped(self) -> int:
        return self.emitted - len(self._buf)

    def events(self) -> List[Event]:
        return list(self._buf)

    def last(self, n: int) -> List[Event]:
        if n <= 0:
            return []
        return list(self._buf)[-n:]

    def clear(self) -> None:
        self._buf.clear()

    def format_tail(self, n: int = 30) -> str:
        """Human-readable last-``n`` events — attached to stuck-scheduler
        exceptions so the failure carries its own flight recorder."""
        if not self.enabled:
            return "(tracing disabled — pass a Tracer to the scheduler for "\
                   "an event tail here)"
        tail = self.last(n)
        if not tail:
            return "(no events recorded)"
        lines = [f"last {len(tail)} of {self.emitted} events "
                 f"({self.dropped} dropped from the ring):"]
        for ev in tail:
            args = " ".join(f"{k}={v}" for k, v in ev.args)
            lines.append(f"  [{ev.ts * 1e3:10.3f}ms] {ev.track:>10s} "
                         f"{ev.ph} {ev.name}" + (f" {args}" if args else ""))
        return "\n".join(lines)


#: Shared disabled tracer — the default for instrumented components, so
#: tracing costs one attribute check per emit site when nobody is listening.
NULL_TRACER = Tracer(capacity=1, enabled=False)
