"""Process-wide metrics registry (docs/observability.md).

Three instrument kinds, deliberately small:

* ``Counter``   — monotonically increasing total (``inc``)
* ``Gauge``     — last-written value (``set`` / ``inc``)
* ``Histogram`` — cumulative-bucket distribution (``observe``), Prometheus
                  ``le`` convention (each bucket counts observations ≤ bound,
                  ``+Inf`` bucket == total count)

``MetricsRegistry`` hands out instruments by name (idempotent — asking for
the same name returns the same instrument; asking with a different kind is
an error) and exports the whole registry as Prometheus text format
(``to_prometheus``) or JSON (``to_json``).  ``REGISTRY`` is the process-wide
default the serving CLI exports; tests and libraries create private
registries so runs never bleed into each other.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

#: Default histogram bounds (milliseconds-flavoured: serving step/TTFT times).
DEFAULT_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0, 2500.0, 5000.0)


class Counter:
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, f"counter {self.name} cannot decrease ({amount})"
        self.value += amount

    def sample_lines(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help, "value": self.value}


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def sample_lines(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help, "value": self.value}


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        assert list(buckets) == sorted(buckets), "bucket bounds must ascend"
        self.name, self.help = name, help
        self.bounds: Tuple[float, ...] = tuple(buckets)
        self.bucket_counts = [0] * (len(self.bounds) + 1)  # last == +Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        for i, b in enumerate(self.bounds):
            if value <= b:
                self.bucket_counts[i] += 1
        self.bucket_counts[-1] += 1          # +Inf catches everything

    def cumulative(self) -> List[int]:
        return list(self.bucket_counts)

    def sample_lines(self) -> List[str]:
        lines = []
        for b, c in zip(self.bounds, self.bucket_counts):
            lines.append(f'{self.name}_bucket{{le="{_fmt(b)}"}} {c}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.bucket_counts[-1]}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return lines

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help, "count": self.count,
                "sum": self.sum,
                "buckets": {**{_fmt(b): c for b, c in
                               zip(self.bounds, self.bucket_counts)},
                            "+Inf": self.bucket_counts[-1]}}


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class MetricsRegistry:
    """Name → instrument map with Prometheus / JSON export."""

    def __init__(self):
        self._instruments: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        assert _NAME_RE.match(name), f"invalid metric name {name!r}"
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name, help, **kwargs)
        assert isinstance(inst, cls), \
            f"metric {name!r} already registered as {inst.kind}"
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[Any]:
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    # -- export -------------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format, one HELP/TYPE block per
        instrument (tools/check_trace.py validates parseability)."""
        lines: List[str] = []
        for name in self.names():
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.sample_lines())
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> Dict[str, Any]:
        return {name: self._instruments[name].to_json()
                for name in self.names()}


#: Process-wide default registry (`launch/serve.py --metrics-out` exports it).
REGISTRY = MetricsRegistry()
