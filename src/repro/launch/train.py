"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --steps 200 --batch 8 --seq 256 --reduced --elitekv --ckpt-dir /tmp/ck

On this CPU container use ``--reduced`` (tiny same-family config); on a real
TPU slice drop it and point ``--mesh`` at the production mesh.  The loop is
fault-tolerant: checkpoints are committed atomically and a restart resumes
from the newest committed step with a deterministic data stream.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.core.convert import pick_dims
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import lm
from repro.optim import schedule as sched_lib
from repro.optim.adamw import AdamWConfig
from repro.runtime import train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="constant", choices=["constant", "cosine", "wsd"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--elitekv", action="store_true")
    ap.add_argument("--cache-ratio", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--moe-impl", default="ragged")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.elitekv and cfg.n_attn_layers:
        cfg = dataclasses.replace(cfg, elitekv=pick_dims(cfg, args.cache_ratio, align=16))

    key = jax.random.PRNGKey(args.seed)
    params, buffers = lm.init(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"elitekv={cfg.elitekv.enabled} "
          f"cache/token/layer={cfg.elitekv.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)}")

    if args.schedule == "constant":
        sched = sched_lib.constant(args.lr)
    elif args.schedule == "cosine":
        sched = sched_lib.cosine(args.lr, warmup=args.steps // 20 + 1, total=args.steps)
    else:
        sched = sched_lib.wsd(args.lr, warmup=args.steps // 20 + 1,
                              stable=args.steps // 2, decay=args.steps // 3 + 1)

    tc = train_loop.TrainConfig(
        optimizer=AdamWConfig(), lr=args.lr, schedule=sched,
        grad_accum=args.grad_accum, moe_impl=args.moe_impl)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                    batch_size=args.batch, seed=args.seed))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()

    def cb(step, metrics):
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  gnorm {float(metrics['grad_norm']):.2f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)

    params, opt_state, history = train_loop.train(
        params, buffers, cfg, tc, iter(data), args.steps,
        checkpointer=ckpt, ckpt_every=args.ckpt_every, callback=cb)
    print(f"final loss: {history[-1][1]:.4f}  ({args.steps} steps, "
          f"{time.time() - t0:.0f}s)")
    return history


if __name__ == "__main__":
    main()
