"""Serving driver: batched greedy generation over the compressed EliteKV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --reduced --elitekv --batch 4 --prompt-len 32 --new-tokens 32

Prints per-request outputs plus the measured cache footprint vs the vanilla
baseline (the paper's headline quantity).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cache import model_cache_floats_per_token
from repro.core.convert import pick_dims
from repro.models import lm
from repro.runtime import serve_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--elitekv", action="store_true")
    ap.add_argument("--cache-ratio", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    base = get_config(args.arch)
    if args.reduced:
        base = base.reduced()
    cfg = base
    if args.elitekv and cfg.n_attn_layers:
        cfg = dataclasses.replace(cfg, elitekv=pick_dims(cfg, args.cache_ratio, align=16))

    key = jax.random.PRNGKey(args.seed)
    params, buffers = lm.init(key, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                                 0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out, stats = serve_loop.generate(params, buffers, cfg, prompts,
                                     args.new_tokens)
    dt = time.time() - t0
    base_floats = model_cache_floats_per_token(base)
    elite_floats = model_cache_floats_per_token(cfg)
    print(f"arch={cfg.name} elitekv={cfg.elitekv.enabled}")
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({stats.decoded_tokens / max(dt, 1e-9):.1f} tok/s incl. compile)")
    print(f"cache floats/token: {elite_floats} vs baseline {base_floats} "
          f"→ ratio {elite_floats / max(base_floats, 1):.3f}")
    print(f"measured attention cache: {stats.cache_bytes / 2**20:.2f} MiB")
    for b in range(min(2, args.batch)):
        print(f"  req{b}: {out[b, :16].tolist()} ...")
    return out


if __name__ == "__main__":
    main()
