"""Serving driver over the compressed EliteKV cache.

Batch mode — lockstep greedy generation (contiguous cache):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --reduced --elitekv --batch 4 --prompt-len 32 --new-tokens 32

Prints per-request outputs plus the measured cache footprint vs the vanilla
baseline (the paper's headline quantity).

Request-stream mode — continuous batching over the paged pool:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --reduced --elitekv --stream --requests 16 --rate 0.5 \
        --max-slots 4 --block-size 16 --num-blocks 128

``--stream`` replaces the fixed batch with a Poisson arrival process
(``--rate`` requests per decode step, exponential inter-arrivals, seeded):
prompt lengths and generation budgets are sampled per request, the
``runtime.serve_loop.Scheduler`` admits arrivals into free slots mid-flight,
prefills their prompts in ``--prefill-chunk``-token chunks — up to
``--prefill-lanes`` sequences' chunks packed into one forward — interleaved
with decode steps (0 = whole prompt at admission), retires sequences on EOS
or budget, and recycles their pool blocks immediately.  ``--admission
preempt`` (default) admits without reservation and, when the pool runs dry,
preempts the youngest resident — recompute-prefill of its generated prefix,
or host swap with ``--eviction swap``; ``--admission watermark`` keeps the
legacy worst-case reservation for comparison.  ``--temperature`` /
``--top-p`` select per-request sampling (temperature 0 = greedy); each
request gets the PRNG seed ``--sample-seed + uid``, so reruns reproduce
token-for-token — including across preemptions.  ``--speculate K`` switches
decode to self-speculative draft/verify macro-steps (``--draft-rank R``
picks the rank-truncated draft; 0 = full-rank): each step proposes up to K
tokens per resident with the cheap draft and verifies them in one
full-model forward, advancing ``1 + accepted`` tokens per verify — greedy
streams stay identical to plain decode.  ``--prefix-cache`` shares prompt
blocks across requests (content-addressed, copy-on-write — docs/serving.md);
``--shared-prefix N`` prepends a common N-token system prefix to every
stream prompt so the cache has something to hit.  The run ends by printing
the scheduler metrics line:

    completed / decode steps / decoded tokens / tok/s — throughput
    ttft_steps (+ per prompt-length bucket), ttft_ms p50/p95
                                         — time-to-first-token (sim + wall)
    step_ms p50/p95                      — per-decode-step latency
    blocks high-water/naive, reuse×      — peak pool blocks vs the sum of
                                           per-request worst cases; reuse > 1
                                           is paging's memory win
    occ / preempt(swap) / prefill_batch  — mean pool occupancy, evictions
                                           (and how many used host swap),
                                           mean lanes per prefill forward

plus the pool accounting (live vs allocated bytes, block size, free blocks).
``--pool-dtype int8`` stores every pooled stream as symmetric-absmax int8
rows with per-token f32 scales — the paged kernels dequantize in-register —
roughly quartering bytes/token at a small quality cost (docs/serving.md has
the parity/quality wall).  docs/serving.md walks through every field.

Observability (docs/observability.md): ``--trace out.json`` records the run
into a ring-buffer tracer and writes a Chrome trace-event timeline — open it
at https://ui.perfetto.dev — with one row per pool slot (request residency),
plus scheduler phase spans, pool block churn, and occupancy counters;
``--metrics-out metrics.prom`` exports the process-wide metrics registry in
Prometheus text format after the drain.  Tracing never perturbs the run:
traced and untraced streams are token-identical (regression-tested).
Summarise a written trace offline with
``python -m repro.launch.diagnose trace-summary out.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cache import model_cache_floats_per_token
from repro.core.convert import pick_dims
from repro.models import lm
from repro.obs import REGISTRY, Tracer, write_chrome_trace
from repro.runtime import serve_loop


def serve_stream(params, buffers, cfg, args):
    """Poisson request-stream mode: exercises admission, mid-flight prefill,
    retirement and block recycling; prints the scheduler metrics."""
    rng = np.random.default_rng(args.seed)
    tracer = Tracer(capacity=args.trace_capacity) if args.trace else None
    if tracer is not None:
        from repro.kernels import ops
        ops.set_kernel_tracer(tracer)       # eager kernel dispatches, if any
    scfg = serve_loop.SchedulerConfig(
        max_slots=args.max_slots, block_size=args.block_size,
        num_blocks=args.num_blocks, eos_id=args.eos_id,
        max_new_tokens=args.new_tokens,
        max_len=args.shared_prefix + args.prompt_len + args.new_tokens + 1,
        prefill_chunk_tokens=args.prefill_chunk,
        prefill_batch_lanes=args.prefill_lanes,
        admission=args.admission, eviction=args.eviction,
        speculate_k=args.speculate, draft_rank=args.draft_rank,
        prefix_cache=args.prefix_cache,
        cache_dtype="int8" if args.pool_dtype == "int8" else jnp.float32,
        sparse_topk_blocks=args.sparse_topk,
        sparse_recent_blocks=args.sparse_recent)
    # multi-device serving: a (dp, tp) mesh sliced into per-replica submeshes
    # (launch/mesh.py) — tp head-shards attention inside each replica, dp adds
    # independent scheduler replicas behind the router (runtime/router.py)
    meshes = None
    if args.tp > 1 or args.dp > 1:
        from repro.launch.mesh import make_serving_mesh, replica_meshes
        meshes = replica_meshes(make_serving_mesh(tp=args.tp, dp=args.dp))
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg, tracer=tracer,
                                 metrics=REGISTRY,
                                 mesh=meshes[0] if meshes else None)
    p_lo = min(4, args.prompt_len)          # sampling floors, valid even for
    n_lo = min(4, args.new_tokens)          # --prompt-len/--new-tokens < 4
    shared = (rng.integers(0, cfg.vocab_size, args.shared_prefix)
              .astype(np.int32) if args.shared_prefix else None)
    t = 0.0
    reqs = []
    for i in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(p_lo, args.prompt_len + 1))
                              ).astype(np.int32)
        if shared is not None:
            prompt = np.concatenate([shared, prompt])
        reqs.append(serve_loop.Request(
            uid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(n_lo, args.new_tokens + 1)),
            arrival=t,
            temperature=args.temperature, top_p=args.top_p,
            seed=args.sample_seed + i))
    if args.dp > 1:
        from repro.runtime.router import Router
        router = Router(params, buffers, cfg, scfg, num_replicas=args.dp,
                        meshes=meshes, tracer=tracer, metrics=REGISTRY)
        rep = router.run(reqs)
        pool0 = router.replicas[0].pool
        print(f"arch={cfg.name} stream [tp={args.tp} dp={args.dp} "
              f"devices={args.tp * args.dp}]: {rep.summary()}")
        print(rep.per_replica_table())
        print(f"pool/device: {pool0.bytes_per_token_per_device()}B/token "
              f"(global {pool0.bytes_per_token()}B/token, tp={pool0.tp}); "
              f"{args.dp} replicas x {scfg.num_blocks} blocks x "
              f"{scfg.block_size} tokens")
        if tracer is not None:
            path = write_chrome_trace(args.trace, tracer)
            print(f"trace: {tracer.emitted} events ({tracer.dropped} dropped "
                  f"by the ring) -> {path} (open in https://ui.perfetto.dev)")
        if args.metrics_out:
            with open(args.metrics_out, "w", encoding="utf-8") as f:
                f.write(REGISTRY.to_prometheus())
            print(f"metrics: {len(REGISTRY.names())} instruments -> "
                  f"{args.metrics_out} (Prometheus text format)")
        return rep
    report = sched.run(reqs)
    stats = sched.pool.stats()
    tptag = f" [tp={args.tp}]" if args.tp > 1 else ""
    print(f"arch={cfg.name} stream{tptag}: {report.summary()}")
    if args.tp > 1:
        print(f"pool/device: {sched.pool.bytes_per_token_per_device()}B/token "
              f"(global {sched.pool.bytes_per_token()}B/token, "
              f"tp={sched.pool.tp})")
    if scfg.prefill_chunk_tokens:
        print(f"chunked prefill: {report.prefill_chunks} forwards of "
              f"<= {scfg.prefill_chunk_tokens} tokens x {scfg.chunk_lanes} "
              f"lanes (mean {report.mean_prefill_batch:.2f} live) "
              f"interleaved with decode")
    if scfg.speculate_k:
        print(f"speculative decode [k={scfg.speculate_k} "
              f"rank={scfg.draft_rank or 'full'}]: "
              f"accepted {report.draft_accepted}/{report.draft_proposed} "
              f"draft tokens (rate {report.acceptance_rate:.2f}, "
              f"mean {report.mean_accepted:.2f}/window) over "
              f"{report.draft_forwards} draft + {report.decode_steps} verify "
              f"forwards -> {report.tokens_per_forward:.2f} tokens/forward")
    if scfg.sparse_topk_blocks:
        print(f"sparse decode [topk={report.sparse_topk} "
              f"recent={report.sparse_recent}]: "
              f"mean {report.mean_selected_blocks:.1f}/"
              f"{report.mean_candidate_blocks:.1f} blocks attended per lane "
              f"over {report.sparse_steps} decode forwards")
    if scfg.prefix_cache:
        print(f"prefix cache: hit_rate={report.prefix_cache_hit_rate:.2f} "
              f"({report.prefix_cache_hit_tokens} prompt tokens served from "
              f"cache across {report.prefix_cache_hits} hits / "
              f"{report.prefix_cache_misses} misses), "
              f"cow_copies={report.cow_copies}, "
              f"retained_blocks={report.blocks_retained}")
    if report.preemptions:
        print(f"preemption [{scfg.eviction}]: {report.preemptions} evictions "
              f"across {report.preempted_requests} requests "
              f"(host swaps out/in {report.swap_outs}/{report.swap_ins}, "
              f"{report.swapped_bytes / 2**10:.1f}KiB out); "
              f"mean occupancy {report.mean_occupancy:.2f}")
    print(f"pool: block_size={stats.block_size} blocks={stats.num_blocks} "
          f"high_water={report.pool_high_water_blocks} "
          f"free_after_drain={stats.blocks_free} "
          f"dtype={report.pool_dtype} "
          f"bytes_per_token={report.pool_bytes_per_token} "
          f"allocated_bytes_peak={report.pool_allocated_bytes_peak / 2**20:.2f}MiB")
    if report.block_reuse_ratio > 1.0:
        print(f"block reuse: peak {report.pool_high_water_blocks} blocks served "
              f"a workload whose naive footprint is {report.naive_blocks} "
              f"({report.block_reuse_ratio:.2f}x)")
    if report.phase_ms:
        print(f"phases: {report.phase_table()} "
              f"(step wall {report.step_wall_ms_total:.0f}ms)")
    if tracer is not None:
        path = write_chrome_trace(args.trace, tracer)
        print(f"trace: {report.trace_events} events "
              f"({report.trace_dropped} dropped by the ring) -> {path} "
              f"(open in https://ui.perfetto.dev)")
    if args.metrics_out:
        with open(args.metrics_out, "w", encoding="utf-8") as f:
            f.write(REGISTRY.to_prometheus())
        print(f"metrics: {len(REGISTRY.names())} instruments -> "
              f"{args.metrics_out} (Prometheus text format)")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--elitekv", action="store_true")
    ap.add_argument("--cache-ratio", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # request-stream (continuous batching) mode
    ap.add_argument("--stream", action="store_true",
                    help="Poisson request stream through the paged scheduler")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per decode step")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=128)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="per-lane per-step chunked-prefill token budget "
                         "(0 = whole prompt at admission)")
    ap.add_argument("--prefill-lanes", type=int, default=0,
                    help="mid-prefill sequences packed per chunked-prefill "
                         "forward (0 = max-slots, 1 = one request per chunk)")
    ap.add_argument("--admission", choices=("preempt", "watermark"),
                    default="preempt",
                    help="preempt: admit on demand, evict youngest on "
                         "OutOfBlocks; watermark: legacy worst-case "
                         "reservation (never preempts)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix blocks across requests "
                         "(content-addressed cache, copy-on-write; "
                         "docs/serving.md)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token system prefix to every "
                         "stream prompt (exercises --prefix-cache hits)")
    ap.add_argument("--pool-dtype", choices=("f32", "int8"), default="f32",
                    help="paged-pool page storage: f32, or int8 symmetric "
                         "absmax quantization with per-token scales and "
                         "fused in-kernel dequant (docs/serving.md)")
    ap.add_argument("--eviction", choices=("recompute", "swap"),
                    default="recompute",
                    help="preemption mechanism: recompute the evicted prefix "
                         "or swap the cached streams to host memory")
    ap.add_argument("--sparse-topk", type=int, default=0,
                    help="latent-space sparse decode: attend only the top-K "
                         "blocks scored against per-block latent summaries, "
                         "plus --sparse-recent newest blocks (0 = dense; "
                         "K >= blocks-per-chain reproduces dense exactly)")
    ap.add_argument("--sparse-recent", type=int, default=2,
                    help="newest chain blocks always attended under "
                         "--sparse-topk (the in-progress block plus a short "
                         "local-context tail)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="self-speculative decode: draft tokens per resident "
                         "per step (0 = plain one-token decode)")
    ap.add_argument("--draft-rank", type=int, default=0,
                    help="joint-factor rank of the draft model (0 or >= "
                         "d_ckv = full-rank draft, acceptance 1)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for stream requests (0 = greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1 = full softmax)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base PRNG seed; request i samples with seed+i")
    # multi-device serving (docs/serving.md#sharded-serving)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: shard absorbed attention "
                         "heads and the k_e pool pages over a 'model' mesh "
                         "axis (token streams stay bit-identical)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replicas: N independent schedulers "
                         "behind a least-loaded router (needs tp*dp devices; "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    # observability (docs/observability.md)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event timeline of the stream "
                         "run to this path (view at ui.perfetto.dev)")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="tracer ring-buffer capacity (oldest events drop "
                         "beyond this)")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics registry in Prometheus text "
                         "format to this path after the run")
    args = ap.parse_args(argv)

    base = get_config(args.arch)
    if args.reduced:
        base = base.reduced()
    cfg = base
    if args.elitekv and cfg.n_attn_layers:
        cfg = dataclasses.replace(cfg, elitekv=pick_dims(cfg, args.cache_ratio, align=16))

    if args.tp < 1 or args.dp < 1:
        ap.error("--tp and --dp must be >= 1")
    if args.sparse_topk < 0 or args.sparse_recent < 0:
        ap.error("--sparse-topk and --sparse-recent must be >= 0")
    if args.sparse_topk > 0 and args.speculate > 0:
        ap.error("--sparse-topk and --speculate are mutually exclusive "
                 "(the multi-query verify window has no single selection "
                 "query; see docs/serving.md)")
    if args.sparse_topk > 0 and not args.stream:
        ap.error("--sparse-topk selects blocks in the paged decode path; "
                 "add --stream")
    if (args.sparse_topk > 0 and args.admission == "preempt"
            and args.eviction == "recompute"):
        ap.error("--sparse-topk with preempt admission needs --eviction swap "
                 "(recompute prefill cannot reproduce sparse-generated "
                 "streams; docs/serving.md#sparse-decode)")
    if (args.tp > 1 or args.dp > 1) and not args.stream:
        ap.error("--tp/--dp shard the paged serving path; add --stream")
    if args.tp > 1 and cfg.elitekv.enabled and cfg.n_kv_heads % args.tp:
        ap.error(f"--tp {args.tp} must divide n_kv_heads={cfg.n_kv_heads} "
                 "(see pad_cfg_for_tp in distributed/sharding.py)")

    key = jax.random.PRNGKey(args.seed)
    params, buffers = lm.init(key, cfg)
    if args.stream:
        if not cfg.elitekv.enabled:
            ap.error("--stream requires --elitekv (paged pool stores the "
                     "compressed streams)")
        if args.rate <= 0:
            ap.error("--rate must be > 0 (mean arrivals per decode step)")
        return serve_stream(params, buffers, cfg, args)
    if args.trace or args.metrics_out:
        ap.error("--trace/--metrics-out instrument the paged scheduler; "
                 "add --stream")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                                 0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out, stats = serve_loop.generate(params, buffers, cfg, prompts,
                                     args.new_tokens)
    dt = time.time() - t0
    base_floats = model_cache_floats_per_token(base)
    elite_floats = model_cache_floats_per_token(cfg)
    print(f"arch={cfg.name} elitekv={cfg.elitekv.enabled}")
    print(f"generated {out.shape} in {dt:.1f}s "
          f"({stats.decoded_tokens / max(dt, 1e-9):.1f} tok/s incl. compile)")
    print(f"cache floats/token: {elite_floats} vs baseline {base_floats} "
          f"→ ratio {elite_floats / max(base_floats, 1):.3f}")
    print(f"measured attention cache: {stats.cache_bytes / 2**20:.2f} MiB")
    for b in range(min(2, args.batch)):
        print(f"  req{b}: {out[b, :16].tolist()} ...")
    return out


if __name__ == "__main__":
    main()
