import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run profiler: top live buffers + per-collective breakdown for a cell.

The "profile" available without hardware is the partitioned HLO — this tool
is the lens the §Perf hypothesis loop looks through.

  python -m repro.launch.diagnose --arch tinyllama_1_1b --shape train_4k

Offline trace analysis (docs/observability.md) — summarise a Chrome trace
written by ``launch/serve.py --trace``:

  python -m repro.launch.diagnose trace-summary trace.json [--top 8]

prints the phase-time table, kernel-span totals, the per-request lifecycle
table (TTFT / residency / retirement reason), the most-preempted requests,
and an ASCII pool-occupancy timeline — the terminal view of what Perfetto
renders graphically.  Traces from a data-parallel run (``--dp N``) add a
per-replica occupancy sparkline block (from the router's ``r{i}_``-prefixed
pool counters) and a replica-imbalance line (max/min requests admitted,
from the ``route`` instants).
"""
import argparse
import json
import re
import sys
from collections import Counter, defaultdict
from pathlib import Path

import numpy as np

_SPARK = " ▁▂▃▄▅▆▇█"


def _sparkline(samples, width):
    """Bin (ts, value) samples into ``width`` columns of block glyphs; each
    column shows the max value seen in its time bin (last value carried
    forward through empty bins — counters hold between updates)."""
    if not samples:
        return "", 0.0
    t0, t1 = samples[0][0], samples[-1][0]
    span = max(t1 - t0, 1e-9)
    peak = max(v for _, v in samples) or 1.0
    cols = [None] * width
    for ts, v in samples:
        c = min(int((ts - t0) / span * width), width - 1)
        cols[c] = v if cols[c] is None else max(cols[c], v)
    out, last = [], 0.0
    for c in cols:
        last = last if c is None else c
        out.append(_SPARK[round(last / peak * (len(_SPARK) - 1))])
    return "".join(out), peak


def trace_summary(argv):
    ap = argparse.ArgumentParser(
        prog="diagnose trace-summary",
        description="summarise a Chrome trace written by serve.py --trace")
    ap.add_argument("trace", help="trace-event JSON path")
    ap.add_argument("--top", type=int, default=8,
                    help="rows in the preempted/requests tables")
    ap.add_argument("--width", type=int, default=64,
                    help="columns in the occupancy timeline")
    args = ap.parse_args(argv)

    events = json.loads(Path(args.trace).read_text())["traceEvents"]
    spans = defaultdict(lambda: [0.0, 0])     # (cat, name) -> [ms, calls]
    reqs = defaultdict(dict)                  # uid -> lifecycle timestamps
    preempts = Counter()
    occupancy, slots = [], []
    replica_occ = defaultdict(list)           # replica id -> (ts, blocks)
    routed = Counter()                        # replica id -> admissions
    for e in events:
        ph, name, uid = e.get("ph"), e.get("name", ""), \
            (e.get("args") or {}).get("uid")
        if ph == "X":
            agg = spans[(e.get("cat", "event"), name)]
            agg[0] += e.get("dur", 0.0) / 1e3
            agg[1] += 1
        elif ph == "i" and uid is not None:
            if name in ("submit", "first_token", "retire"):
                reqs[uid][name] = e["ts"]
                if name == "retire":
                    reqs[uid]["reason"] = e["args"].get("reason", "?")
                    reqs[uid]["tokens"] = e["args"].get("tokens", 0)
            elif name == "preempt":
                preempts[uid] += 1
        elif ph == "C" and name == "pool_blocks_used":
            occupancy.append((e["ts"], float(e["args"]["value"])))
        elif ph == "C" and name == "slots_occupied":
            slots.append((e["ts"], float(e["args"]["value"])))
        elif ph == "C":
            m = re.match(r"r(\d+)_pool_blocks_used$", name)
            if m:
                replica_occ[int(m.group(1))].append(
                    (e["ts"], float(e["args"]["value"])))
        if ph == "i" and name == "route":
            routed[(e.get("args") or {}).get("replica", "?")] += 1

    for cat, title in (("phase", "phase time"), ("kernel", "kernel spans"),
                       ("swap", "swap traffic")):
        rows = sorted(((n, ms, c) for (ct, n), (ms, c) in spans.items()
                       if ct == cat), key=lambda r: -r[1])
        if not rows:
            continue
        total = sum(ms for _, ms, _ in rows) or 1.0
        print(f"== {title} ==")
        for n, ms, c in rows:
            print(f"  {n:<14s} {ms:9.1f}ms  {c:5d} calls  "
                  f"{100 * ms / total:3.0f}%")

    done = sorted(reqs.items())
    if done:
        print(f"== requests ({len(done)} submitted, "
              f"{sum('retire' in r for _, r in done)} retired) ==")
        print(f"  {'uid':>4s} {'ttft_ms':>8s} {'total_ms':>9s} "
              f"{'tokens':>6s} {'reason':<7s} preempts")
        for uid, r in done[:args.top]:
            ttft = (f"{(r['first_token'] - r['submit']) / 1e3:8.1f}"
                    if "first_token" in r and "submit" in r else f"{'—':>8s}")
            total = (f"{(r['retire'] - r['submit']) / 1e3:9.1f}"
                     if "retire" in r and "submit" in r else f"{'—':>9s}")
            print(f"  {uid:>4d} {ttft} {total} {r.get('tokens', 0):>6} "
                  f"{r.get('reason', 'live'):<7s} {preempts.get(uid, 0)}")
        if len(done) > args.top:
            print(f"  ... {len(done) - args.top} more")
    if preempts:
        worst = ", ".join(f"req{u}×{c}" for u, c in
                          preempts.most_common(args.top))
        print(f"== top preempted requests ==\n  {worst} "
              f"({sum(preempts.values())} evictions total)")

    for samples, title, unit in ((occupancy, "pool occupancy", "blocks"),
                                 (slots, "slots occupied", "slots")):
        line, peak = _sparkline(samples, args.width)
        if line:
            t_ms = (samples[-1][0] - samples[0][0]) / 1e3
            print(f"== {title} (peak {peak:.0f} {unit} over {t_ms:.0f}ms) ==")
            print(f"  [{line}]")

    if replica_occ:                           # data-parallel run (router)
        print(f"== per-replica pool occupancy ({len(replica_occ)} "
              f"replicas) ==")
        for i in sorted(replica_occ):
            line, peak = _sparkline(replica_occ[i], args.width)
            print(f"  r{i} [{line}] peak {peak:.0f} blocks, "
                  f"{routed.get(i, 0)} routed")
    if routed:
        counts = [routed.get(i, 0) for i in sorted(routed)]
        lo, hi = min(counts), max(counts)
        ratio = "inf" if lo == 0 else f"{hi / lo:.2f}"
        print(f"== replica imbalance ==\n  routed={counts} max/min={ratio} "
              f"(1.00 = perfectly even)")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "trace-summary":
        return trace_summary(argv[1:])
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-elitekv", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--scan", action="store_true", help="use scan lowering")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)

    from repro.launch import dryrun
    res, compiled, cfg = dryrun.lower_cell(
        args.arch, args.shape, args.multi_pod, elitekv=not args.no_elitekv,
        seq_parallel=not args.no_seq_parallel, unroll=True,
        param_dtype=args.param_dtype, return_artifacts=True)
    txt = compiled.as_text()

    print(f"peak/device: {res['memory']['peak_estimate_bytes']/2**30:.2f} GiB  "
          f"(temp {res['memory']['temp_bytes']/2**30:.2f}, "
          f"args {res['memory']['argument_bytes']/2**30:.2f})")
    print(f"flops/device: {res['flops_per_device']:.3e}   "
          f"bytes/device: {res['bytes_accessed_per_device']:.3e}")
    print(f"collectives/device: {res['collective_bytes_per_device']/2**30:.2f} GiB")
    for k, v in sorted(res["collectives"].items()):
        print(f"  {k:20s} n={v['count']:4d}  {v['bytes']/2**30:7.2f} GiB")

    # biggest single collectives
    print("\n== largest collectives ==")
    rows = []
    for line in txt.splitlines():
        m = dryrun._COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        nbytes = 0
        for sm in dryrun._SHAPE_RE.finditer(m.group(1)):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in dryrun._DTYPE_BYTES:
                continue
            n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
            nbytes += n * dryrun._DTYPE_BYTES[dt]
        meta = re.search(r'op_name="([^"]*)"', line)
        rows.append((nbytes, m.group(2), m.group(1)[:60],
                     (meta.group(1)[-80:] if meta else "")))
    rows.sort(reverse=True)
    agg = Counter()
    names = {}
    for nbytes, op, shp, name in rows:
        key = (op, shp)
        agg[key] += nbytes
        names.setdefault(key, name)
    for (op, shp), b in agg.most_common(args.top):
        print(f"  {b/2**30:7.2f} GiB  {op:18s} {shp}")
        print(f"           └─ {names[(op, shp)]}")

    # biggest shapes overall
    print("\n== largest tensor shapes in HLO ==")
    sizes = Counter()
    counts = Counter()
    for m in re.finditer(r"(f32|bf16|s32|u32|f16|s8|u8|pred)\[([\d,]+)\]", txt):
        dims = [int(x) for x in m.group(2).split(",")]
        b = int(np.prod(dims)) * dryrun._DTYPE_BYTES[m.group(1)]
        key = f"{m.group(1)}[{m.group(2)}]"
        sizes[key] = b
        counts[key] += 1
    for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[:args.top]:
        print(f"  {v/2**30:7.2f} GiB  ×{counts[k]:4d}  {k}")


if __name__ == "__main__":
    main()
