import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run profiler: top live buffers + per-collective breakdown for a cell.

The "profile" available without hardware is the partitioned HLO — this tool
is the lens the §Perf hypothesis loop looks through.

  python -m repro.launch.diagnose --arch tinyllama_1_1b --shape train_4k
"""
import argparse
import re
from collections import Counter

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-elitekv", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--scan", action="store_true", help="use scan lowering")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.launch import dryrun
    res, compiled, cfg = dryrun.lower_cell(
        args.arch, args.shape, args.multi_pod, elitekv=not args.no_elitekv,
        seq_parallel=not args.no_seq_parallel, unroll=True,
        param_dtype=args.param_dtype, return_artifacts=True)
    txt = compiled.as_text()

    print(f"peak/device: {res['memory']['peak_estimate_bytes']/2**30:.2f} GiB  "
          f"(temp {res['memory']['temp_bytes']/2**30:.2f}, "
          f"args {res['memory']['argument_bytes']/2**30:.2f})")
    print(f"flops/device: {res['flops_per_device']:.3e}   "
          f"bytes/device: {res['bytes_accessed_per_device']:.3e}")
    print(f"collectives/device: {res['collective_bytes_per_device']/2**30:.2f} GiB")
    for k, v in sorted(res["collectives"].items()):
        print(f"  {k:20s} n={v['count']:4d}  {v['bytes']/2**30:7.2f} GiB")

    # biggest single collectives
    print("\n== largest collectives ==")
    rows = []
    for line in txt.splitlines():
        m = dryrun._COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        nbytes = 0
        for sm in dryrun._SHAPE_RE.finditer(m.group(1)):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in dryrun._DTYPE_BYTES:
                continue
            n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
            nbytes += n * dryrun._DTYPE_BYTES[dt]
        meta = re.search(r'op_name="([^"]*)"', line)
        rows.append((nbytes, m.group(2), m.group(1)[:60],
                     (meta.group(1)[-80:] if meta else "")))
    rows.sort(reverse=True)
    agg = Counter()
    names = {}
    for nbytes, op, shp, name in rows:
        key = (op, shp)
        agg[key] += nbytes
        names.setdefault(key, name)
    for (op, shp), b in agg.most_common(args.top):
        print(f"  {b/2**30:7.2f} GiB  {op:18s} {shp}")
        print(f"           └─ {names[(op, shp)]}")

    # biggest shapes overall
    print("\n== largest tensor shapes in HLO ==")
    sizes = Counter()
    counts = Counter()
    for m in re.finditer(r"(f32|bf16|s32|u32|f16|s8|u8|pred)\[([\d,]+)\]", txt):
        dims = [int(x) for x in m.group(2).split(",")]
        b = int(np.prod(dims)) * dryrun._DTYPE_BYTES[m.group(1)]
        key = f"{m.group(1)}[{m.group(2)}]"
        sizes[key] = b
        counts[key] += 1
    for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[:args.top]:
        print(f"  {v/2**30:7.2f} GiB  ×{counts[k]:4d}  {k}")


if __name__ == "__main__":
    main()
