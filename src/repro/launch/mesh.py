"""Production mesh factory.

Importing this module never touches jax device state; meshes are built on
demand (so tests see 1 CPU device unless the dry-run set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh``, or ``{}`` on jax versions
    that predate ``jax.sharding.AxisType`` (e.g. 0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds the 2-pod "pod" axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires host_device_count set)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
