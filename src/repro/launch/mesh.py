"""Production mesh factory.

Importing this module never touches jax device state; meshes are built on
demand (so tests see 1 CPU device unless the dry-run set XLA_FLAGS first).
"""
from __future__ import annotations

import jax
import numpy as np


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh``, or ``{}`` on jax versions
    that predate ``jax.sharding.AxisType`` (e.g. 0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds the 2-pod "pod" axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(shape=None, axes=("data", "model")):
    """Small mesh for CPU multi-device tests.

    The default shape is derived from ``jax.device_count()`` — the largest
    ``(n // 2, 2)`` grid that fits, falling back to ``(1, 1)`` on
    single-device hosts — so construction never raises on a plain CPU dev
    box that didn't set ``--xla_force_host_platform_device_count``.
    """
    if shape is None:
        n = jax.device_count()
        shape = (n // 2, 2) if n >= 2 else (1,) * len(axes)
        shape = shape[: len(axes)] + (1,) * (len(axes) - len(shape))
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_serving_mesh(tp: int = 1, dp: int = 1):
    """``(dp, tp)`` serving mesh over the first ``dp*tp`` host devices.

    Axis names follow the training convention: replicas over ``"data"``,
    absorbed attention heads over ``"model"``.  The router slices this into
    per-replica submeshes with :func:`replica_meshes`.
    """
    devices = jax.devices()
    need = dp * tp
    if need > len(devices):
        raise ValueError(
            f"serving mesh needs {need} devices (tp={tp} x dp={dp}) but only "
            f"{len(devices)} are visible; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} on CPU")
    arr = np.array(devices[:need]).reshape(dp, tp)
    return jax.sharding.Mesh(arr, ("data", "model"))


def replica_meshes(mesh):
    """Split a ``("data", "model")`` serving mesh into one independent
    ``("model",)`` submesh per data-parallel replica.

    Each replica's scheduler runs its pool and shard_map collectives on a
    disjoint device slice, so replicas never synchronize with each other.
    """
    devs = np.asarray(mesh.devices)          # [dp, tp]
    return [jax.sharding.Mesh(devs[i], ("model",)) for i in range(devs.shape[0])]
