import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: the dry-run builds the 256/512-chip
# production mesh out of placeholder host devices (this file only — smoke
# tests and benches see the real single CPU device).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the full sharded step function
(train_step = fwd + bwd + AdamW update; serve_step = decode + cache update
+ argmax), lowers it against allocation-free ShapeDtypeStructs carrying the
production NamedShardings, compiles, and records:

  * compiled.memory_analysis()   — per-device bytes (proves it fits)
  * compiled.cost_analysis()     — per-device HLO FLOPs / bytes accessed
  * collective schedule          — op-type totals parsed from the partitioned
                                   HLO text (all-gather / all-reduce /
                                   reduce-scatter / all-to-all / permute)

Results land in experiments/dryrun/<mesh>/<arch>__<shape>[__variant].json and
feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k
  python -m repro.launch.dryrun --arch yi_6b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, cell_applicable, get_config, input_specs, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.convert import pick_dims
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig
from repro.runtime import serve_loop, train_loop

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"= (\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collectives(hlo_text: str):
    """Per-device collective bytes by op type, from the RESULT shapes of every
    collective in the partitioned HLO (post-optimization text prints operands
    by name only, so result shapes are the reliable source; for reduce-scatter
    this undercounts by ~group_size — noted in EXPERIMENTS.md)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(m.group(1)):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        rec = out.setdefault(op, {"bytes": 0, "count": 0})
        rec["bytes"] += nbytes
        rec["count"] += 1
    return out


# ---------------------------------------------------------------------------

def build_cfg(arch: str, shape: ShapeConfig, plan: shd.MeshPlan,
              elitekv: bool = True, cache_ratio: float = 0.25,
              moe_impl: str = "ep", overrides=None,
              unroll: bool = True) -> ModelConfig:
    cfg = get_config(arch)
    cfg = shd.pad_cfg_for_tp(cfg, plan.tp)
    # XLA cost analysis counts while-loop bodies ONCE, so attention q-chunk
    # loops are python-unrolled for truthful FLOPs; the layer scan stays a
    # scan (realistic memory) and its per-layer cost is recovered via the
    # unroll=1 vs unroll=2 delta (see lower_cell).  The mamba chunk scan is
    # NOT unrolled: its inner-loop flops are elementwise (no GEMMs), <1% of
    # the block — the undercount is negligible and unrolling explodes the HLO.
    cfg = dataclasses.replace(
        cfg, dtype=jnp.bfloat16,
        scan_layers=True, attn_chunk_unroll=unroll, ssm_unroll=False,
        ssm_chunk=128)
    if elitekv and cfg.n_attn_layers > 0:
        ek = pick_dims(cfg, cache_ratio, align=128)
        cfg = dataclasses.replace(cfg, elitekv=ek)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def _sds(tree, shardings):
    """ShapeDtypeStructs with attached shardings (no allocation)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               elitekv: bool = True, cache_ratio: float = 0.25,
               moe_impl: str = "ep", moment_dtype: str | None = None,
               seq_parallel: bool = True, param_dtype: str = "float32",
               overrides=None, unroll: bool = True, return_artifacts: bool = False,
               decode_fsdp: bool | None = None, decode_seq_tp: bool = True,
               opt_chunk: int = 0, loss_chunk: int = 0):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = shd.plan_for_mesh(mesh, seq_parallel=seq_parallel)
    if shape.kind == "decode":
        if decode_fsdp is None:
            # §Perf: inference keeps weights replicated across data (no
            # per-step ZeRO-3 all-gathers) whenever the bf16 weights fit the
            # TP shards (~≤8 GiB/dev); the 100B+ MoE giants keep FSDP
            decode_fsdp = get_config(arch).param_count() * 2 / plan.tp > 8e9
        if not decode_fsdp:
            plan = shd.plan_for_mesh(mesh, fsdp=False, seq_parallel=seq_parallel)
    cfg = build_cfg(arch, shape, plan, elitekv=elitekv,
                    cache_ratio=cache_ratio, overrides=overrides, unroll=unroll)
    if loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=loss_chunk)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "x".join(map(str, mesh.devices.shape)),
                "skipped": True, "reason": reason}

    P_ = jax.sharding.PartitionSpec
    extra = {}

    def build_and_compile(cfg):
        nonlocal extra
        key = jax.random.PRNGKey(0)
        pshapes, bshapes = jax.eval_shape(lambda k: lm.init(k, cfg), key)
        if param_dtype != "float32":
            pshapes = _cast_tree(pshapes, jnp.dtype(param_dtype))
        pspecs = shd.param_pspecs(pshapes, cfg, plan)
        pshard = jax.tree.map(plan.named, pspecs, is_leaf=lambda x: isinstance(x, P_))
        bshard = jax.tree.map(lambda s: plan.named(P_(*([None] * s.ndim))), bshapes)
        params_in = _sds(pshapes, pshard)
        buffers_in = _sds(bshapes, bshard)

        ispecs = input_specs(cfg, shape, dtype=jnp.bfloat16)
        in_pspecs = shd.input_pspecs(cfg, shape, plan)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=plan.named(in_pspecs[k]))
                    for k, v in ispecs.items()}

        t0 = time.time()
        if shape.kind == "train":
            # default moments: int8 for the ≥100B MoE giants, else fp32
            md = moment_dtype or ("int8" if cfg.param_count() > 5e10 else "float32")
            tc = train_loop.TrainConfig(
                optimizer=AdamWConfig(moment_dtype=md, update_chunk=opt_chunk),
                moe_impl=moe_impl if cfg.n_experts else "ragged")
            constrain = shd.make_constrain(plan, cfg, shape.seq_len, shape.global_batch)
            step = train_loop.make_train_step(cfg, tc, mesh=mesh, constrain=constrain,
                                              data_axes=plan.dp_axes)
            oshapes = jax.eval_shape(lambda p: train_loop.init_opt_state(p, tc), pshapes)
            ospecs = shd.opt_pspecs(oshapes, pshapes, cfg, plan, md)
            oshard = jax.tree.map(plan.named, ospecs, is_leaf=lambda x: isinstance(x, P_))
            opt_in = _sds(oshapes, oshard)
            fn = jax.jit(step, donate_argnums=(0, 2))
            lowered = fn.lower(params_in, buffers_in, opt_in, batch_in)
            extra = {"moment_dtype": md}
        elif shape.kind == "prefill":
            params_in = _sds(_cast_tree(pshapes, jnp.bfloat16), pshard)
            cshapes = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
            cspecs = shd.cache_pspecs(cshapes, cfg, plan, shape.global_batch)
            cache_in = _sds(cshapes, jax.tree.map(plan.named, cspecs,
                                                  is_leaf=lambda x: isinstance(x, P_)))
            constrain = shd.make_constrain(plan, cfg, shape.seq_len, shape.global_batch)
            step = serve_loop.make_prefill_step(
                cfg, mesh=mesh, constrain=constrain,
                moe_impl=moe_impl if cfg.n_experts else "ragged", data_axes=plan.dp_axes)
            fn = jax.jit(step, donate_argnums=(3,))
            lowered = fn.lower(params_in, buffers_in, batch_in, cache_in)
        else:  # decode
            params_in = _sds(_cast_tree(pshapes, jnp.bfloat16), pshard)
            cshapes = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
            cspecs = shd.cache_pspecs(cshapes, cfg, plan, shape.global_batch,
                                      seq_over_tp=decode_seq_tp)
            cache_in = _sds(cshapes, jax.tree.map(plan.named, cspecs,
                                                  is_leaf=lambda x: isinstance(x, P_)))
            constrain = shd.make_constrain(plan, cfg, shape.seq_len,
                                           shape.global_batch, decode=True,
                                           seq_over_tp=decode_seq_tp)
            step = serve_loop.make_decode_step(
                cfg, mesh=mesh, constrain=constrain,
                moe_impl=moe_impl if cfg.n_experts else "ragged", data_axes=plan.dp_axes)
            tok_in = list(batch_in.values())[0]
            fn = jax.jit(step, donate_argnums=(3,))
            lowered = fn.lower(params_in, buffers_in, tok_in, cache_in)
        t_lower = time.time() - t0
        t0 = time.time()
        # dump post-SPMD-partitioning HLO: the CPU backend upcasts bf16 GEMMs
        # to f32 (convert_convert fusions), inflating *optimized-text* byte
        # counts ~2×; the post-SPMD dump still carries true bf16 shapes.
        import shutil
        import tempfile
        dump = tempfile.mkdtemp(prefix="spmd_dump_")
        try:
            compiled = lowered.compile(compiler_options={
                "xla_dump_to": dump,
                "xla_dump_hlo_pass_re": "spmd-partitioning"})
            spmd_files = sorted(Path(dump).glob("*after_spmd-partitioning*.txt"))
            spmd_text = spmd_files[-1].read_text() if spmd_files else None
        finally:
            shutil.rmtree(dump, ignore_errors=True)
        return compiled, spmd_text, t_lower, time.time() - t0

    # --- pass 1: flop/collective probe (attention chunks unrolled) ---
    compiled, spmd_text, t_lower, t_compile = build_and_compile(cfg)
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(spmd_text or compiled.as_text())
    # --- memory pass: the PRODUCTION lowering (inner chunk loops as scans —
    # the unrolled probe inflates temp memory because buffer assignment does
    # not reuse across unrolled chunk blocks) ---
    if cfg.attn_chunk_unroll:
        cfg_mem = dataclasses.replace(cfg, attn_chunk_unroll=False)
        compiled_mem, _, _, t_cm = build_and_compile(cfg_mem)
        ma = compiled_mem.memory_analysis()
        t_compile += t_cm
    else:
        ma = compiled.memory_analysis()

    # --- pass 2: unroll=2 — the delta is exactly one layer-scan body;
    #     total = base + (n_super - 1) · delta  (XLA counts loop bodies once) ---
    n_super = cfg.num_layers // cfg.block_period
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    if n_super > 1:
        cfg2 = dataclasses.replace(cfg, scan_unroll=2)
        compiled2, spmd_text2, _, t_c2 = build_and_compile(cfg2)
        ca2 = compiled2.cost_analysis() or {}
        colls2 = parse_collectives(spmd_text2 or compiled2.as_text())
        mult = n_super - 1
        dflops = max(0.0, float(ca2.get("flops", 0.0)) - flops)
        dbytes = max(0.0, float(ca2.get("bytes accessed", 0.0)) - bytes_acc)
        flops += mult * dflops
        bytes_acc += mult * dbytes
        merged = {}
        for op in set(colls) | set(colls2):
            b1 = colls.get(op, {"bytes": 0, "count": 0})
            b2 = colls2.get(op, {"bytes": 0, "count": 0})
            merged[op] = {
                "bytes": b1["bytes"] + mult * max(0, b2["bytes"] - b1["bytes"]),
                "count": b1["count"] + mult * max(0, b2["count"] - b1["count"]),
            }
        colls = merged
        t_compile += t_c2
    ca = dict(ca, flops=flops)
    ca["bytes accessed"] = bytes_acc

    n_chips = int(np.prod(mesh.devices.shape))
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "kind": shape.kind, "skipped": False,
        "chips": n_chips,
        "elitekv": dataclasses.asdict(cfg.elitekv),
        "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "tokens_per_step": tokens,
        "cache_floats_per_token": (
            cfg.elitekv.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)
            * cfg.n_attn_layers),
        "flops_per_device": float(ca.get("flops", -1)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        "collectives": colls,
        "collective_bytes_per_device": sum(v["bytes"] for v in colls.values()),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **extra,
    }
    if return_artifacts:
        return result, compiled, cfg
    return result


def run_cell(args) -> dict:
    res = lower_cell(args.arch, args.shape, args.multi_pod,
                     elitekv=not args.no_elitekv, cache_ratio=args.cache_ratio,
                     moment_dtype=args.moment_dtype or None,
                     seq_parallel=not args.no_seq_parallel,
                     param_dtype=args.param_dtype,
                     decode_fsdp=args.decode_fsdp or None,
                     decode_seq_tp=not args.no_decode_seq_tp,
                     opt_chunk=args.opt_chunk, loss_chunk=args.loss_chunk)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    out_dir = Path(args.out) / mesh_tag
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{args.arch}__{args.shape}"
    if args.variant:
        tag += f"__{args.variant}"
    path = out_dir / f"{tag}.json"
    path.write_text(json.dumps(res, indent=1))
    print(json.dumps(res, indent=1))
    if not res.get("skipped"):
        gb = res["memory"]["peak_estimate_bytes"] / 2**30
        print(f"[dryrun] {tag} mesh={mesh_tag}: peak/device ≈ {gb:.2f} GiB, "
              f"flops/dev {res['flops_per_device']:.3e}, "
              f"coll/dev {res['collective_bytes_per_device']/2**20:.1f} MiB, "
              f"compile {res['compile_s']}s", file=sys.stderr)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(list_archs()), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-elitekv", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--cache-ratio", type=float, default=0.25)
    ap.add_argument("--moment-dtype", default="")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--variant", default="")
    ap.add_argument("--decode-fsdp", action="store_true",
                    help="re-enable ZeRO-3 weight gathers at decode (baseline)")
    ap.add_argument("--no-decode-seq-tp", action="store_true",
                    help="disable context-parallel decode cache (baseline)")
    ap.add_argument("--opt-chunk", type=int, default=0)
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        import subprocess
        archs = [a for a in list_archs() if not a.startswith("llama2_13b")]
        for mp in (False, True):
            for arch in archs:
                for shape in SHAPES:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--out", args.out]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>", " ".join(cmd), flush=True)
                    subprocess.run(cmd, check=False)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    run_cell(args)


if __name__ == "__main__":
    main()
