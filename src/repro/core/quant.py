"""Symmetric int8 quantization for the paged latent pool (docs/serving.md).

The pool stores the compressed ``(k_e, c_kv)`` streams; quantizing them to
int8 halves-to-quarters the bytes per token on top of EliteKV's structural
reduction (ROADMAP "Quantized latent pool").  The scheme is deliberately the
simplest one whose representation depends ONLY on the token's values:

* **per-token rows** — one f32 scale per pool slot per stream, absmax over
  every trailing dim of that token's row.  Per-*block* scales would make a
  block's contents depend on which tokens shared it and in what order they
  arrived (an incremental scatter into a half-full block either requantizes
  neighbours or freezes a chunk-boundary-dependent scale), which would break
  the golden invariants (chunked == one-shot, preempted == undisturbed).
  Per-token scales make quantization a pure function of the token row, so
  every existing identity survives the dtype bit-exactly.
* **symmetric absmax** — ``scale = max(absmax, eps) / 127``;
  ``q = round(x / scale)`` never needs the clip (|x|/scale <= 127 by
  construction; the clip only guards float rounding).  Scales are strictly
  positive even for all-zero rows, and the round-trip error is bounded
  elementwise by ``scale / 2`` (tests/test_property.py pins both).

Dequantization is one multiply — ``q.astype(f32) * scale`` — cheap enough to
fuse into the Pallas decode/verify kernels' block-table walk
(``kernels/elite_decode.py``) and the resumed-chunk prefix gather
(``core/elite_attention.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

#: symmetric int8 range: q in [-127, 127] (the -128 code is never produced)
INT8_MAX = 127
#: absmax floor so all-zero / denormal rows still get a strictly positive
#: scale (q = 0 exactly, round-trip error 0)
SCALE_EPS = 1e-8


def quantize_rows(x):
    """Quantize ``x [N, ...]`` row-wise → ``(q int8 [N, ...], scale f32 [N])``.

    One scale per leading-axis row, absmax over all trailing dims.  A pure
    function of each row — no cross-row or history dependence (the property
    the serving invariants rely on; see module docstring).
    """
    xf = jnp.asarray(x, jnp.float32)
    trailing = tuple(range(1, xf.ndim))
    absmax = jnp.max(jnp.abs(xf), axis=trailing) if trailing \
        else jnp.abs(xf)
    scale = jnp.maximum(absmax, SCALE_EPS) / INT8_MAX
    s = scale.reshape(scale.shape + (1,) * len(trailing))
    q = jnp.clip(jnp.round(xf / s), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q, scale):
    """Invert :func:`quantize_rows`: ``q int8 [N, ...] * scale [N] → f32``.

    ``scale`` broadcasts over the trailing dims of ``q``; accepts any
    leading shape as long as ``scale.shape == q.shape[:scale.ndim]``.
    """
    q = jnp.asarray(q)
    s = jnp.asarray(scale, jnp.float32)
    return q.astype(jnp.float32) * s.reshape(s.shape + (1,) * (q.ndim - s.ndim))


def roundtrip_rows(x, batch_dims: int = 1):
    """Quantize → dequantize each token row of ``x`` (leading ``batch_dims``
    axes index rows; the rest is the row).  Returns ``x``'s dtype/shape.

    Prefill attention over a quantized pool runs this on the *current*
    chunk's streams so in-chunk attention sees exactly the values any later
    pool read will dequantize — without it, chunked and one-shot prefill
    would attend over different keys and the golden invariants
    (tests/test_quant.py) would only hold approximately.
    """
    flat = x.reshape((-1,) + x.shape[batch_dims:])
    q, s = quantize_rows(flat)
    return dequantize(q, s).reshape(x.shape).astype(x.dtype)


def is_int8(dtype) -> bool:
    """True when ``dtype`` names the quantized pool mode (``"int8"`` string
    or any int8 dtype object)."""
    try:
        return jnp.dtype(dtype) == jnp.int8
    except TypeError:
        return False
