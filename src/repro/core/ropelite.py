"""RoPElite (paper Alg. 1): greedy per-head search for elite RoPE chunks.

For each attention head, find the ``r`` 2-D frequency chunks whose rotation the
head's attention scores depend on most: at every greedy step, add the chunk
``j`` minimizing  ||s(full RoPE) − s(RoPE on selected ∪ {j})||₁.

Identity used for an O(r·C) search (paper App. B: one forward pass, all layers
and heads in parallel):  with  D_c = s_rot(c) − s_plain(c)  the per-chunk score
delta, s(M) − s(full) = −Σ_{c∉M} D_c =: −G(M).  The candidate distance is then
||G − D_j||₁ and the update after picking j* is  G ← G − D_{j*}.

GQA generalization: elite sets live per **KV head**; candidate distances are
summed over the query heads of the group (keys are shared, so the chunk choice
must be, too).

This is stage 1 of the pipeline in docs/architecture.md — the selected chunks
decide which key dims stay rotary while the rest feed the joint low-rank
latent (core/lrd.py).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rope as rope_lib


def _chunked(x):
    """[..., D] → [..., C, 2] interleaved-pair view."""
    return x.reshape(x.shape[:-1] + (x.shape[-1] // 2, 2))


def _pair_scores(qc, kc, q_group: int):
    """qc [B,S,nh,2], kc [B,S,nkv,2] → scores [B,nh,S,S]."""
    B, S, nh, _ = qc.shape
    nkv = kc.shape[2]
    qg = qc.reshape(B, S, nkv, q_group, 2)
    s = jnp.einsum("bqhgt,bkht->bhgqk", qg, kc, preferred_element_type=jnp.float32)
    return s.reshape(B, nh, S, S)


def _chunk_delta(qch, kch, qch_rot, kch_rot, c, q_group):
    """D_c for one chunk index (same for all heads)."""
    take = lambda t: jax.lax.dynamic_index_in_dim(t, c, axis=3, keepdims=False)
    return (_pair_scores(take(qch_rot), take(kch_rot), q_group)
            - _pair_scores(take(qch), take(kch), q_group))


def greedy_search_layer(q, k, positions, theta: float, q_group: int, r: int,
                        causal: bool = True) -> jnp.ndarray:
    """Greedy elite-chunk search for one layer.

    q [B,S,nh,dh] / k [B,S,nkv,dh] — PRE-rotation projections.
    Returns elite chunk indices in selection order: [nkv, r] int32.
    """
    B, S, nh, dh = q.shape
    nkv = k.shape[2]
    C = dh // 2
    q_rot = rope_lib.apply_rope(q, positions, theta)
    k_rot = rope_lib.apply_rope(k, positions, theta)
    qch, kch = _chunked(q), _chunked(k)
    qch_rot, kch_rot = _chunked(q_rot), _chunked(k_rot)

    wmask = (jnp.tril(jnp.ones((S, S), jnp.float32)) if causal
             else jnp.ones((S, S), jnp.float32))[None, None]

    def delta(c):
        return _chunk_delta(qch, kch, qch_rot, kch_rot, c, q_group)

    # G = sum_c D_c  (scores(full) - scores(none)), accumulated chunk-by-chunk
    def acc(G, c):
        return G + delta(c), None
    G, _ = jax.lax.scan(acc, jnp.zeros((B, nh, S, S), jnp.float32), jnp.arange(C))

    selected = jnp.zeros((nkv, C), bool)
    order = jnp.zeros((nkv, r), jnp.int32)

    def iteration(carry, i):
        G, selected, order = carry

        def cand(_, c):
            d = jnp.sum(jnp.abs(G - delta(c)) * wmask, axis=(0, 2, 3))   # [nh]
            return None, d.reshape(nkv, q_group).sum(-1)                 # [nkv]

        _, dist = jax.lax.scan(cand, None, jnp.arange(C))                # [C,nkv]
        dist = jnp.where(selected.T, jnp.inf, dist)
        j_star = jnp.argmin(dist, axis=0).astype(jnp.int32)              # [nkv]
        # subtract the newly-selected chunk's delta per kv head
        take_h = lambda t, idx: jnp.take_along_axis(                      # per-head gather
            t, idx[None, None, :, None, None], axis=3)[..., 0, :]
        idx_q = jnp.repeat(j_star, q_group)                               # [nh]
        idx_k = j_star                                                    # [nkv]
        d_sel = (_pair_scores(take_h(qch_rot, idx_q), take_h(kch_rot, idx_k), q_group)
                 - _pair_scores(take_h(qch, idx_q), take_h(kch, idx_k), q_group))
        G = G - d_sel
        selected = selected.at[jnp.arange(nkv), j_star].set(True)
        order = order.at[:, i].set(j_star)
        return (G, selected, order), None

    (G, selected, order), _ = jax.lax.scan(
        iteration, (G, selected, order), jnp.arange(r))
    return order


# ---------------------------------------------------------------------------
# baseline selection methods (paper §4.3.1)
# ---------------------------------------------------------------------------

def uniform_selection(C: int, r: int, nkv: int) -> jnp.ndarray:
    """Evenly spaced chunks across the frequency range, same for all heads."""
    idx = np.unique(np.round(np.linspace(0, C - 1, r)).astype(np.int32))
    while len(idx) < r:  # de-dup fallback for tiny C
        extra = [i for i in range(C) if i not in idx][: r - len(idx)]
        idx = np.sort(np.concatenate([idx, np.array(extra, np.int32)]))
    return jnp.tile(jnp.asarray(idx, jnp.int32)[None], (nkv, 1))


def contribution_selection(q, k, q_group: int, r: int) -> jnp.ndarray:
    """Hong et al. style: rank chunks by L2 contribution ‖q_c‖·‖k_c‖ per head."""
    qch, kch = _chunked(q), _chunked(k)                      # [B,S,H,C,2]
    qn = jnp.sqrt(jnp.mean(jnp.sum(qch.astype(jnp.float32) ** 2, -1), (0, 1)))  # [nh,C]
    kn = jnp.sqrt(jnp.mean(jnp.sum(kch.astype(jnp.float32) ** 2, -1), (0, 1)))  # [nkv,C]
    nkv = kn.shape[0]
    contrib = qn.reshape(nkv, q_group, -1).sum(1) * kn                  # [nkv,C]
    _, idx = jax.lax.top_k(contrib, r)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# whole-model search
# ---------------------------------------------------------------------------

def _layer_qk(layer_params, cfg, x):
    """Projections for one attention layer from captured normed input x."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, layer_params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, layer_params["wk"].astype(dt))
    return q, k


def search_model(params, buffers, cfg, batch, r: int, method: str = "greedy",
                 moe_impl: str = "dense", causal: bool = True
                 ) -> Dict[int, jnp.ndarray]:
    """Elite chunks for every attention layer of a *baseline* (non-elite) model.

    Returns {absolute_layer_index: [n_kv, r] int32} (greedy order preserved).
    """
    from repro.models import lm
    assert not cfg.elitekv.enabled, "search runs on the baseline model"
    caps = lm.capture_attn_inputs(params, buffers, cfg, batch, moe_impl=moe_impl)
    P_ = cfg.block_period
    out: Dict[int, jnp.ndarray] = {}
    positions = None
    for p_key, xs in caps.items():
        p_pos = int(p_key[1:])
        n_super = xs.shape[0]
        for s in range(n_super):
            layer_idx = s * P_ + p_pos
            lp = jax.tree.map(lambda t: t[s], params["blocks"][p_key]["attn"])
            x = xs[s]
            if positions is None or positions.shape[0] != x.shape[1]:
                positions = jnp.arange(x.shape[1])
            q, k = _layer_qk(lp, cfg, x)
            if method == "greedy":
                out[layer_idx] = greedy_search_layer(
                    q, k, positions, cfg.rope_theta, cfg.q_group, r, causal)
            elif method == "uniform":
                out[layer_idx] = uniform_selection(cfg.head_dim // 2, r, cfg.n_kv_heads)
            elif method == "contribution":
                out[layer_idx] = contribution_selection(q, k, cfg.q_group, r)
            else:
                raise ValueError(method)
    return out


def score_distance(q, k, positions, theta, q_group, elite_idx, causal=True) -> jnp.ndarray:
    """‖s(full) − s(elite set)‖₁ — diagnostic used by tests/benchmarks."""
    dh = q.shape[-1]
    C = dh // 2
    nkv, r = elite_idx.shape
    mask_kv = jnp.zeros((nkv, C), bool).at[
        jnp.arange(nkv)[:, None], elite_idx].set(True)
    mask_q = jnp.repeat(mask_kv, q_group, axis=0)
    q_sub = rope_lib.apply_rope_subset(q, positions, theta, mask_q)
    k_sub = rope_lib.apply_rope_subset(k, positions, theta, mask_kv)
    q_rot = rope_lib.apply_rope(q, positions, theta)
    k_rot = rope_lib.apply_rope(k, positions, theta)

    def scores(qq, kk):
        kk = jnp.repeat(kk, q_group, axis=2) if q_group > 1 else kk
        return jnp.einsum("bqhd,bkhd->bhqk", qq, kk,
                          preferred_element_type=jnp.float32)

    s_full = scores(q_rot, k_rot)
    s_sub = scores(q_sub, k_sub)
    S = q.shape[1]
    w = (jnp.tril(jnp.ones((S, S))) if causal else jnp.ones((S, S)))[None, None]
    return jnp.sum(jnp.abs(s_full - s_sub) * w, axis=(0, 2, 3))
