"""Rotary position embeddings, including EliteKV's per-head *partial* RoPE.

Conventions
-----------
* Interleaved pairing: chunk ``i`` of a head vector is ``(x[2i], x[2i+1])``,
  matching the paper's  I = {[2i : 2i+1]}.
* Chunk ``i`` carries frequency  theta_i = base ** (-2 i / d_h)  — chunk 0 is the
  highest frequency, chunk d_h/2 - 1 the lowest ("numbers increase from high to
  low frequencies", paper Fig. 2).
* *RoPElite* models store, per KV head, the ``r`` elite frequencies
  (``elite_freqs`` — the gathered theta values, not indices: projection columns are
  permuted at conversion time so elite chunks occupy the first ``2r`` dims).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunk_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    """theta_i for each 2-D chunk: shape [d_head // 2], descending frequency."""
    i = jnp.arange(d_head // 2, dtype=jnp.float32)
    return theta ** (-2.0 * i / d_head)


def cos_sin(positions: jnp.ndarray, freqs: jnp.ndarray):
    """cos/sin tables.

    positions: [...P] int/float; freqs: [...F] → cos,sin of shape [...P, ...F]
    (outer product over the trailing freq axes).
    """
    ang = positions.reshape(positions.shape + (1,) * freqs.ndim).astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate interleaved pairs of the last axis of x.

    x: [..., 2C]; cos/sin broadcastable to [..., C].
    """
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    x2 = x.reshape(x.shape[:-1] + (x.shape[-1] // 2, 2))
    x_even, x_odd = x2[..., 0], x2[..., 1]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_even * sin + x_odd * cos
    out = jnp.stack([out_even, out_odd], axis=-1).reshape(x.shape)
    return out.astype(orig_dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Full RoPE.  x: [B, S, H, D]; positions: [B, S] or [S]."""
    f = chunk_freqs(x.shape[-1], theta)                       # [C]
    cos, sin = cos_sin(positions, f)                          # [B, S, C] or [S, C]
    if positions.ndim == 1:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return rotate(x, cos, sin)


def apply_rope_subset(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                      chunk_mask: jnp.ndarray) -> jnp.ndarray:
    """RoPE applied only where ``chunk_mask`` is True (per-head masks allowed).

    x: [B, S, H, D]; chunk_mask: [C] or [H, C] booleans.  Non-masked chunks pass
    through unrotated (the RoPElite "linear" dims).  Used by the greedy search.
    """
    f = chunk_freqs(x.shape[-1], theta)
    cos, sin = cos_sin(positions, f)                          # [S|B,S, C]
    if positions.ndim == 1:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    m = chunk_mask.astype(jnp.float32)
    if chunk_mask.ndim == 2:                                  # [H, C]
        m = m[None, None, :, :]
    # masked rotation == rotate with angle*mask (identity where mask=0)
    cos_m = cos * m + (1.0 - m)
    sin_m = sin * m
    return rotate(x, cos_m, sin_m)


def apply_elite_rope(x: jnp.ndarray, positions: jnp.ndarray,
                     elite_freqs: jnp.ndarray) -> jnp.ndarray:
    """Per-head RoPE over the packed elite dims.

    x: [B, S, H, 2r] — the (pre-permuted) elite slice; elite_freqs: [H, r]
    (theta values per head).  positions: [S] or [B, S].
    """
    B, S, H, r2 = x.shape
    r = r2 // 2
    assert elite_freqs.shape == (H, r), (elite_freqs.shape, (H, r))
    if positions.ndim == 1:
        ang = positions[:, None, None].astype(jnp.float32) * elite_freqs[None]   # [S,H,r]
        cos, sin = jnp.cos(ang)[None], jnp.sin(ang)[None]                        # [1,S,H,r]
    else:
        ang = positions[:, :, None, None].astype(jnp.float32) * elite_freqs[None, None]
        cos, sin = jnp.cos(ang), jnp.sin(ang)                                    # [B,S,H,r]
    return rotate(x, cos, sin)


def expand_kv_to_q(per_kv: jnp.ndarray, q_group: int) -> jnp.ndarray:
    """[n_kv, ...] → [n_kv * q_group, ...]: query head h uses kv head h // q_group."""
    return jnp.repeat(per_kv, q_group, axis=0)
