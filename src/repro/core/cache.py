"""KV-cache size accounting + the block-paged compressed-KV pool.

Size formulas (paper §3.2), per token per attention layer, in floats:
    vanilla MHA/GQA:      2 · n_kv · d_h
    RoPElite + J-LRD:     2 · r · n_kv + d_ckv
    RoPElite + S-LRD:     2 · r · n_kv + d_ck + d_cv
Mamba layers hold O(1) state instead (conv + ssm), reported separately.

Paged pool
----------
``PagedKVPool`` stores the compressed ``(k_e, c_kv)`` streams of every
attention layer in fixed-size token *blocks* shared across sequences
(vLLM-style).  Sequences own ragged chains of blocks via per-sequence block
tables; chains grow one block at a time on demand and recycle the moment a
sequence retires.  Device pages are plain jax arrays handed to jitted steps
and reassigned; all bookkeeping (free list, tables, lengths) is host-side
Python.

``BlockManager`` layers the serving scheduler's *policy* on top of the pool:
admission gating (preempt-on-demand vs the legacy watermark reservation),
resident registration, and the two eviction mechanisms — recompute (free the
victim's blocks; the scheduler re-prefills its prefix later) and host
swap-out (copy the victim's cached streams to host memory and restore them
block-exactly on re-admission).

Cross-request prefix caching (docs/serving.md §prefix caching)
--------------------------------------------------------------
Real traffic shares huge prompt prefixes (system prompts, few-shot
templates, multi-turn history).  With ``BlockManager(prefix_cache=True)``
the pool's physical blocks become *shareable*: every block carries a
refcount, full prompt-token blocks are content-addressed by a chained hash
(``prefix_block_hashes`` — block ``i``'s key commits to every token before
it), and an admission-time ``lookup_prefix`` splices already-cached blocks
into a newcomer's chain instead of re-prefilling them.  Writes go through a
copy-on-write barrier (``PagedKVPool.make_private``): a resident that would
write into a block another chain references gets a private copy first, so
no write is ever visible through another resident's chain.  Retired
prefixes' blocks (refcount 0) are *retained* in an LRU rather than freed —
still servable to future lookups, reclaimed oldest-first only when the
allocator runs dry.  EliteKV's ~75% cache compression multiplies with this
dedup: the same physical pool holds proportionally more distinct prefixes.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.obs.trace import NULL_TRACER


def attn_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.elitekv.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)


def model_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.n_attn_layers * attn_cache_floats_per_token(cfg)


def ssm_state_floats(cfg: ModelConfig, batch: int) -> int:
    n_ssm = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "ssm")
    per = (cfg.ssm_conv - 1) * cfg.d_inner + cfg.d_inner * cfg.ssm_state
    return n_ssm * per * batch


def cache_ratio(cfg_elite: ModelConfig, cfg_base: ModelConfig) -> float:
    """Attention-KV compression ratio vs the unmodified model."""
    a = model_cache_floats_per_token(cfg_elite)
    b = model_cache_floats_per_token(cfg_base)
    return a / b if b else 1.0


class OutOfBlocks(RuntimeError):
    """Raised when the pool cannot satisfy an allocation (caller may retry
    after retiring sequences, or refuse admission)."""


#: Per-BLOCK summary leaf suffixes (sparse decode, docs/serving.md).  A pool
#: built with ``block_summaries=True`` stores, beside the latent key stream,
#: a masked mean and absmax of each block's valid rows:
#:   "<stream>_blkmean" / "<stream>_blkmax"  —  [n_super, num_blocks, d_c] f32
#: Unlike the int8 scale leaves these index the BLOCK axis, not the slot
#: axis, so the slot-generic lifecycle edges (COW copy, host swap) special-
#: case them by name — everything else (truncate, prefix sharing, release)
#: needs nothing: summaries are a pure function of block content.
BLOCK_SUMMARY_SUFFIXES = ("_blkmean", "_blkmax")


def is_block_summary(name: str) -> bool:
    """True for page-leaf names that index blocks rather than slots."""
    return name.endswith(BLOCK_SUMMARY_SUFFIXES)


# ---------------------------------------------------------------------------
# prefix caching: chained block hashes + the content-addressed block cache
# ---------------------------------------------------------------------------

#: Domain separator — the hash chain's root "parent" digest.  Bump on any
#: change to the hashing scheme so stale keys can never alias fresh ones.
_HASH_ROOT = b"elitekv-prefix-v1"


def block_hash(parent: bytes, tokens) -> bytes:
    """Key of one full token block: ``H(parent_hash ‖ block_tokens)``.

    Chaining through ``parent`` makes the key commit to *every* token before
    the block, not just its own — two prompts sharing block ``i``'s tokens
    but differing earlier can never collide (parent-hash dependence)."""
    h = hashlib.sha256(parent)
    h.update(np.asarray(tokens, np.int32).tobytes())
    return h.digest()


def prefix_block_hashes(tokens, block_size: int) -> List[bytes]:
    """Chained hashes of every FULL ``block_size``-token block of ``tokens``.
    A partial tail block has no hash — it is never cached (its content would
    change as the sequence grows into it)."""
    toks = np.asarray(tokens, np.int32)
    out: List[bytes] = []
    parent = _HASH_ROOT
    for i in range(len(toks) // block_size):
        parent = block_hash(parent,
                            toks[i * block_size:(i + 1) * block_size])
        out.append(parent)
    return out


class PrefixCache:
    """Content-addressed map from chained block hashes to physical blocks,
    with LRU retention of unreferenced entries.

    Owned by a ``BlockManager``; the pool consults it on the block lifecycle
    edges: a cached block whose refcount drops to 0 is *retained* (moved to
    the LRU, still servable to lookups) instead of freed, and reclaimed
    oldest-first only when the allocator runs dry.  A cached block is never
    rewritten in place: shared blocks copy-on-write, and a sole owner about
    to rewrite one first ``invalidate``s its claim.
    """

    def __init__(self):
        self._by_hash: Dict[bytes, int] = {}          # chain hash → block
        self._by_block: Dict[int, bytes] = {}         # block → chain hash
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()                 # refcount-0, oldest first
        self.hits = 0                                 # lookups that shared ≥ 1 block
        self.misses = 0                               # lookups that shared none
        self.hit_tokens = 0                           # tokens served from cache
        self.lookup_tokens = 0                        # tokens presented to lookups
        self.reclaimed = 0                            # retained blocks evicted

    @property
    def num_cached(self) -> int:
        return len(self._by_hash)

    @property
    def num_retained(self) -> int:
        return len(self._lru)

    def get(self, h: bytes) -> Optional[int]:
        return self._by_hash.get(h)

    def is_cached(self, block: int) -> bool:
        return block in self._by_block

    def claim(self, h: bytes, block: int) -> bool:
        """Register ``block`` as the physical home of chain hash ``h``.
        First claim wins — a duplicate hash keeps the existing block (the
        newcomer's copy stays private and is freed normally)."""
        if h in self._by_hash or block in self._by_block:
            return False
        self._by_hash[h] = block
        self._by_block[block] = h
        return True

    def on_ref(self, block: int) -> None:
        """``block`` gained a reference: it leaves the reclaimable LRU."""
        self._lru.pop(block, None)

    def retain(self, block: int) -> bool:
        """``block``'s refcount hit 0.  Returns True when the block is cached
        and should be kept (appended as most-recently-used); False means the
        pool frees it normally."""
        if block not in self._by_block:
            return False
        self._lru[block] = None
        self._lru.move_to_end(block)
        return True

    def invalidate(self, block: int) -> None:
        """Drop ``block``'s content claim (sole owner about to rewrite it, or
        a COW copy superseding it).  The block itself stays wherever it is —
        owned by its chain, or freed by the caller."""
        h = self._by_block.pop(block, None)
        if h is not None:
            del self._by_hash[h]
        self._lru.pop(block, None)

    def reclaim(self, n: int) -> List[int]:
        """Evict up to ``n`` retained blocks, least-recently-used first,
        dropping their hash claims.  Returns the blocks (now unowned — the
        caller puts them back on the free list)."""
        out: List[int] = []
        while len(out) < n and self._lru:
            block, _ = self._lru.popitem(last=False)
            h = self._by_block.pop(block)
            del self._by_hash[h]
            self.reclaimed += 1
            out.append(block)
        return out


class BlockAllocator:
    """Host-side free-list over ``num_blocks`` fixed-size token blocks."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.high_water = 0          # max blocks simultaneously in use
        self.total_allocs = 0        # lifetime alloc count (reuse visibility)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        got = [self._free.pop() for _ in range(n)]
        self.total_allocs += n
        self.high_water = max(self.high_water, self.num_used)
        return got

    def free(self, blocks: Sequence[int]) -> None:
        self._free.extend(blocks)

    def reset(self) -> None:
        self._free = list(range(self.num_blocks - 1, -1, -1))


@dataclasses.dataclass
class PoolStats:
    block_size: int
    num_blocks: int
    blocks_in_use: int
    blocks_free: int
    high_water_blocks: int
    total_allocs: int
    live_tokens: int        # sum of sequence lengths
    allocated_tokens: int   # blocks_in_use * block_size (internal fragmentation)
    live_bytes: int
    allocated_bytes: int
    blocks_shared: int = 0     # blocks referenced by more than one chain
    blocks_retained: int = 0   # refcount-0 prefix-cache blocks (reclaimable)
    cow_copies: int = 0        # lifetime copy-on-write block copies
    dtype: str = "float32"     # pool storage dtype ("int8" = quantized)
    bytes_per_token: int = 0   # actual bytes/slot incl. quantization scales


class PagedKVPool:
    """Block-paged device storage for EliteKV's compressed cache streams.

    Pages mirror ``lm.init_cache``'s per-``p_pos`` layout but replace the
    ``[B, max_len, ...]`` leading dims with one flat ``[n_slots, ...]`` token
    axis (``n_slots = num_blocks · block_size``); token ``t`` of block ``b``
    lives at flat slot ``b · block_size + t``.  Only attention layers page —
    serving currently requires an attention-only, EliteKV-enabled config
    (Mamba's O(1) state needs no paging; hybrid support is a ROADMAP item).
    """

    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int,
                 dtype=jnp.float32, tracer=None, mesh=None,
                 tp_axis: str = "model", block_summaries: bool = False):
        assert cfg.elitekv.enabled, "paged pool stores compressed streams only"
        self.trace = tracer or NULL_TRACER   # obs: alloc/free/truncate events
        for p_pos in range(cfg.block_period):
            assert cfg.layer_kind(p_pos) == "attn", \
                "paged serving supports attention-only stacks (see ROADMAP)"
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        # dtype="int8" (string or dtype) selects the quantized pool: stream
        # leaves store symmetric-absmax int8 rows and every stream gains a
        # per-slot f32 scale leaf "<name>_scale" beside it (core/quant.py).
        # Scales keep the [n_super, n_slots, ...] slot axis at position 1, so
        # COW copies, host swap and truncate handle them with zero special
        # cases — they are just more page leaves.
        self.dtype = jnp.dtype(dtype)
        self.quantized = quant.is_int8(self.dtype)
        # block_summaries=True (sparse top-k decode) adds two f32 leaves per
        # latent KEY stream summarizing each block's valid rows — see
        # BLOCK_SUMMARY_SUFFIXES above.  Maintained by the jitted scatter
        # (core/elite_attention.py), copied block-row-wise on COW, carried
        # byte-exactly through host swap, rewritten by recompute prefill.
        self.block_summaries = bool(block_summaries)
        self.allocator = BlockAllocator(num_blocks)
        self._tables: Dict[int, List[int]] = {}   # seq_id → block chain
        self._lengths: Dict[int, int] = {}        # seq_id → live token count
        self._refcount: Dict[int, int] = {}       # block → referencing chains
        self.prefix: Optional[PrefixCache] = None  # set by BlockManager
        self.cow_copies = 0                       # lifetime copy-on-write count
        e = cfg.elitekv
        n_super = cfg.num_layers // cfg.block_period
        n_slots = num_blocks * block_size
        r2 = 2 * e.elite_r

        def _streams():
            tails = {"k_e": (cfg.n_kv_heads, r2)}
            if e.lrd == "joint":
                tails["c"] = (e.d_ckv,)
            else:
                tails["c_k"] = (e.d_ck,)
                tails["c_v"] = (e.d_cv,)
            s = {}
            for name, tail in tails.items():
                s[name] = jnp.zeros((n_super, n_slots) + tail, self.dtype)
                if self.quantized:
                    s[name + "_scale"] = jnp.zeros((n_super, n_slots),
                                                   jnp.float32)
            if self.block_summaries:
                key = "c" if e.lrd == "joint" else "c_k"
                for sfx in BLOCK_SUMMARY_SUFFIXES:
                    s[key + sfx] = jnp.zeros(
                        (n_super, num_blocks) + tails[key], jnp.float32)
            return s

        self.pages = {f"p{p}": _streams() for p in range(cfg.block_period)}

        # Tensor-parallel page placement: the k_e stream shards its kv-head
        # dim over the mesh's TP axis; the head-shared latent and the
        # per-token scales replicate (distributed/sharding.py
        # ``serving_page_pspecs``).  Block ids, chains, refcounts, prefix
        # hashes — everything host-side — stay shard-invariant: every device
        # holds the same slot layout, just a head slice of k_e, so COW / swap
        # / truncate / prefix sharing below never special-case the mesh.
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.tp = 1
        if mesh is not None:
            from repro.distributed import sharding as shardlib
            plan = shardlib.plan_for_mesh(mesh)
            if tp_axis in mesh.shape and mesh.shape[tp_axis] > 1:
                assert cfg.n_kv_heads % mesh.shape[tp_axis] == 0, \
                    (cfg.n_kv_heads, mesh.shape[tp_axis],
                     "kv heads must divide tp (pad_cfg_for_tp)")
                self.tp = mesh.shape[tp_axis]
            specs = shardlib.serving_page_pspecs(cfg, plan)
            self.pages = {
                p_key: {name: jax.device_put(
                            arr, jax.sharding.NamedSharding(mesh, specs[name]))
                        for name, arr in layer.items()}
                for p_key, layer in self.pages.items()}

    # -- allocation plumbing (prefix-cache aware) ---------------------------
    def _alloc(self, n: int) -> List[int]:
        """Allocate ``n`` blocks, reclaiming LRU-retained prefix-cache blocks
        (oldest first) when the free list alone cannot cover the request."""
        short = n - self.allocator.num_free
        if short > 0 and self.prefix is not None:
            evicted = self.prefix.reclaim(short)
            if evicted:
                self.allocator.free(evicted)
                self.trace.instant("free", track="pool", cat="pool", seq=-1,
                                   blocks=evicted, reason="reclaim")
        got = self.allocator.alloc(n)       # raises OutOfBlocks if still short
        for b in got:
            self._refcount[b] = 1
        return got

    def _release_blocks(self, blocks: Sequence[int], seq_id: int,
                        reason: str) -> None:
        """Drop one reference per block.  A block reaching refcount 0 either
        returns to the free list or — when it backs a cached prefix — is
        retained reclaimable in the prefix cache's LRU."""
        freed: List[int] = []
        retained: List[int] = []
        for b in blocks:
            self._refcount[b] -= 1
            if self._refcount[b] > 0:
                continue                    # another chain still reads it
            del self._refcount[b]
            if self.prefix is not None and self.prefix.retain(b):
                retained.append(b)
            else:
                freed.append(b)
        if freed:
            self.allocator.free(freed)
            self.trace.instant("free", track="pool", cat="pool", seq=seq_id,
                               blocks=freed, reason=reason)
        if retained:
            self.trace.instant("retain", track="pool", cat="cache",
                               seq=seq_id, blocks=retained)

    # -- sequence lifecycle -------------------------------------------------
    def ensure_capacity(self, seq_id: int, length: int) -> None:
        """Grow ``seq_id``'s block chain to hold ``length`` tokens (allocates
        lazily on first touch).  Raises OutOfBlocks when the pool is full."""
        table = self._tables.setdefault(seq_id, [])
        need = -(-length // self.block_size) - len(table)
        if need > 0:
            got = self._alloc(need)
            table.extend(got)
            self.trace.instant("alloc", track="pool", cat="pool", seq=seq_id,
                               blocks=got, length=length)
        self._lengths[seq_id] = max(self._lengths.get(seq_id, 0), length)

    def share_prefix(self, seq_id: int, blocks: Sequence[int]) -> None:
        """Splice already-cached ``blocks`` into ``seq_id``'s (empty) chain
        as its head: each gains a reference instead of being re-prefilled.
        The chain's length becomes exactly the shared coverage."""
        table = self._tables.setdefault(seq_id, [])
        assert not table and not self._lengths.get(seq_id, 0), \
            (seq_id, "prefix sharing requires a fresh chain")
        for b in blocks:
            self._refcount[b] = self._refcount.get(b, 0) + 1
            if self.prefix is not None:
                self.prefix.on_ref(b)
        table.extend(blocks)
        self._lengths[seq_id] = len(blocks) * self.block_size
        if blocks:
            self.trace.instant("share", track="pool", cat="cache",
                               seq=seq_id, blocks=list(blocks))

    def make_private(self, seq_id: int, start: int, end: int) -> None:
        """Copy-on-write barrier: before ``seq_id`` writes token positions
        ``[start, end)``, give it exclusive ownership of every covered block.
        A block another chain references is copied device-side into a fresh
        block (the writer's chain repoints; readers keep the original); a
        sole-owner block that backs a cached prefix just drops its content
        claim (no copy needed — nobody else can read it)."""
        if end <= start:
            return
        table = self._tables.get(seq_id, [])
        bs = self.block_size
        for bi in range(start // bs, min(-(-end // bs), len(table))):
            b = table[bi]
            if self._refcount.get(b, 0) > 1:
                new = self._alloc(1)[0]
                src = np.arange(b * bs, (b + 1) * bs)
                dst = np.arange(new * bs, (new + 1) * bs)
                for p_key, layer in self.pages.items():
                    # block-summary leaves index blocks, not slots: copy the
                    # single summary row; every other leaf copies slot-wise
                    self.pages[p_key] = {
                        name: (arr.at[:, new].set(arr[:, b])
                               if is_block_summary(name)
                               else arr.at[:, dst].set(arr[:, src]))
                        for name, arr in layer.items()}
                self._refcount[b] -= 1
                table[bi] = new
                self.cow_copies += 1
                self.trace.instant("cow", track="pool", cat="cache",
                                   seq=seq_id, block=b, copy=new)
            elif self.prefix is not None and self.prefix.is_cached(b):
                self.prefix.invalidate(b)   # sole owner rewrites in place

    def can_fit(self, extra_tokens: int) -> bool:
        avail = self.allocator.num_free + \
            (self.prefix.num_retained if self.prefix is not None else 0)
        return avail * self.block_size >= extra_tokens

    def truncate(self, seq_id: int, length: int) -> None:
        """Shrink ``seq_id`` to ``length`` tokens, releasing tail blocks the
        shorter chain no longer covers (speculative decode rolls rejected
        verify-window tokens back through here — pages are never rewritten,
        the stale slots are simply re-extended over by later growth).
        A released block still referenced by another chain is merely
        un-linked, never freed or rolled back; the next write into a kept
        block that is still shared goes through ``make_private`` first.
        ``length`` must not exceed the current length; 0 keeps the (empty)
        chain registered."""
        assert length >= 0, length
        if seq_id not in self._lengths:     # unknown/freed seq: only the
            assert length == 0, (seq_id, length)   # no-op shrink is legal,
            return                          # and it must not register one
        cur = self._lengths[seq_id]
        assert length <= cur, (seq_id, length, cur)
        table = self._tables.get(seq_id, [])
        keep = -(-length // self.block_size)
        if keep < len(table):
            dropped = table[keep:]
            del table[keep:]
            self._release_blocks(dropped, seq_id, reason="truncate")
        self._lengths[seq_id] = length

    def free_seq(self, seq_id: int) -> None:
        blocks = self._tables.pop(seq_id, [])
        if blocks:
            self._release_blocks(blocks, seq_id, reason="release")
        self._lengths.pop(seq_id, None)

    def reset(self) -> None:
        self.allocator.reset()
        self._tables.clear()
        self._lengths.clear()
        self._refcount.clear()
        self.cow_copies = 0
        if self.prefix is not None:
            self.prefix = PrefixCache()

    def length(self, seq_id: int) -> int:
        return self._lengths.get(seq_id, 0)

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._tables.get(seq_id, []))

    # -- device-side index helpers -----------------------------------------
    @property
    def oob_slot(self) -> int:
        """Scatter sentinel: one past the last flat slot (dropped by
        ``mode="drop"`` writes — used to mask inactive batch lanes)."""
        return self.num_blocks * self.block_size

    def block_table_array(self, seq_ids: Sequence[Optional[int]],
                          max_blocks: int) -> np.ndarray:
        """Padded int32 ``[len(seq_ids), max_blocks]`` table (pad = block 0;
        padded pages are masked out by per-sequence lengths downstream)."""
        out = np.zeros((len(seq_ids), max_blocks), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            t = self._tables.get(sid, [])
            assert len(t) <= max_blocks, (len(t), max_blocks)
            out[i, :len(t)] = t
        return out

    def flat_slots(self, seq_id: int, positions) -> np.ndarray:
        """Flat pool slots for logical ``positions`` of ``seq_id``'s chain:
        position ``p`` lives at ``table[p // bs] · bs + p % bs``.  The single
        source of the slot-layout formula (decode/prefill mappings and host
        swap all route through here)."""
        table = np.asarray(self._tables[seq_id], np.int64)
        pos = np.asarray(positions)
        return table[pos // self.block_size] * self.block_size \
            + pos % self.block_size

    def slot_mapping(self, seq_ids: Sequence[Optional[int]],
                     positions: Sequence[int]) -> np.ndarray:
        """Flat write slots for one token per sequence; inactive lanes
        (seq_id None) map to ``oob_slot``."""
        out = np.full((len(seq_ids),), self.oob_slot, np.int32)
        for i, (sid, pos) in enumerate(zip(seq_ids, positions)):
            if sid is not None:
                out[i] = self.flat_slots(sid, pos)
        return out

    def prefill_slot_mapping(self, seq_id: int, start: int,
                             n_tokens: int, pad_to: int) -> np.ndarray:
        """Flat write slots for ``n_tokens`` consecutive positions starting at
        ``start``, padded with ``oob_slot`` up to ``pad_to`` (prompt padding)."""
        out = np.full((pad_to,), self.oob_slot, np.int32)
        out[:n_tokens] = self.flat_slots(seq_id,
                                         np.arange(start, start + n_tokens))
        return out

    # -- accounting ---------------------------------------------------------
    def floats_per_token(self) -> int:
        return model_cache_floats_per_token(self.cfg)

    def bytes_per_token(self) -> int:
        """Actual pool bytes per token slot, summed over every page leaf —
        int8 stream rows AND their f32 scales in quantized mode (the honest
        capacity number the serving stats report)."""
        n_slots = self.num_blocks * self.block_size
        return sum(a.nbytes // n_slots
                   for layer in self.pages.values() for a in layer.values())

    def bytes_per_token_per_device(self) -> int:
        """Pool bytes per token slot actually resident on EACH device: the
        head-sharded ``k_e`` stream contributes ``1/tp`` of its global bytes,
        replicated leaves contribute in full.  Equals ``bytes_per_token()``
        when unsharded — the per-device-count benchmark scaling row reports
        this number."""
        n_slots = self.num_blocks * self.block_size
        total = 0
        for layer in self.pages.values():
            for name, a in layer.items():
                div = self.tp if name == "k_e" else 1
                total += a.nbytes // div // n_slots
        return total

    def stats(self) -> PoolStats:
        live = sum(self._lengths.values())
        alloc_tok = self.allocator.num_used * self.block_size
        bpt = self.bytes_per_token()
        return PoolStats(
            block_size=self.block_size, num_blocks=self.num_blocks,
            blocks_in_use=self.allocator.num_used,
            blocks_free=self.allocator.num_free,
            high_water_blocks=self.allocator.high_water,
            total_allocs=self.allocator.total_allocs,
            live_tokens=live, allocated_tokens=alloc_tok,
            live_bytes=live * bpt,
            allocated_bytes=alloc_tok * bpt,
            dtype=str(self.dtype), bytes_per_token=bpt,
            blocks_shared=sum(1 for c in self._refcount.values() if c > 1),
            blocks_retained=(self.prefix.num_retained
                             if self.prefix is not None else 0),
            cow_copies=self.cow_copies)


@dataclasses.dataclass
class SwappedSeq:
    """Host-side copy of a preempted sequence's cached streams (swap
    eviction).  ``streams[p_key][name]`` is a ``[n_super, length, ...]``
    numpy array in *token order* — independent of which physical blocks the
    sequence owned, so swap-in may land on a completely different chain.
    ``block_streams`` carries the chain's per-block summary rows (sparse
    pools only) in *chain order* — ``[n_super, n_chain_blocks, ...]`` —
    restored byte-exactly onto whatever blocks swap-in allocates, so block
    selection is invariant under swap."""
    length: int
    streams: Dict[str, Dict[str, np.ndarray]]
    block_streams: Dict[str, Dict[str, np.ndarray]] = \
        dataclasses.field(default_factory=dict)

    def nbytes(self) -> int:
        return sum(a.nbytes for s in self.streams.values() for a in s.values()) \
            + sum(a.nbytes for s in self.block_streams.values()
                  for a in s.values())


class BlockManager:
    """Admission + eviction policy over a ``PagedKVPool``.

    Two admission policies:

    * ``"preempt"`` (default) — no reservation.  A request is admitted as
      soon as its *next allocation* (first prefill chunk, or the swapped-out
      prefix being restored) fits in the free list; residents grow blocks on
      demand and growth may raise ``OutOfBlocks`` mid-flight, which the
      scheduler resolves by preempting the youngest resident.
    * ``"watermark"`` — the legacy reservation policy: the worst-case blocks
      still owed to every registered resident are held back, so admission is
      refused unless the newcomer's full worst case fits in
      ``free − reserved`` and growth can never fail.

    Eviction mechanisms (used by the scheduler's preemption path):

    * ``preempt_recompute`` — drop the victim's blocks; its cached prefix is
      rebuilt by a recompute-prefill after re-admission.  Cheap to evict,
      costs one prefill of the prefix — and under EliteKV that prefill only
      re-fills the low-rank ``(k_e, c_kv)`` streams, the paper's compression
      making recompute proportionally cheaper than for a full KV cache.
    * ``preempt_swap_out`` / ``swap_in`` — copy the victim's live tokens to
      host memory, free the blocks, and scatter the copy back into a fresh
      chain on re-admission.  Costs PCIe traffic instead of FLOPs.

    With ``prefix_cache=True`` the manager additionally runs the
    cross-request prefix cache (``PrefixCache``): ``lookup_prefix`` splices
    cached full prompt blocks into a newcomer's chain, ``register_prefix``
    claims a resident's freshly prefilled full blocks for future lookups,
    and ``prepare_write`` is the copy-on-write barrier callers invoke before
    scattering into a chain.  Eviction, preemption and ``truncate`` all
    respect refcounts — a block another chain references is never freed or
    rolled back.
    """

    def __init__(self, pool: PagedKVPool, policy: str = "preempt",
                 prefix_cache: bool = False):
        assert policy in ("preempt", "watermark"), policy
        self.pool = pool
        self.policy = policy
        if prefix_cache and pool.prefix is None:
            pool.prefix = PrefixCache()
        self._resident_worst: Dict[int, int] = {}   # seq_id → worst-case blocks
        self.preemptions = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_bytes = 0                      # lifetime host-swap traffic

    @property
    def prefix(self) -> Optional[PrefixCache]:
        return self.pool.prefix

    # -- prefix cache (cross-request block sharing) -------------------------
    def lookup_prefix(self, seq_id: int, tokens) -> int:
        """Admission-time cache probe: share the longest cached chain of full
        ``tokens`` blocks into ``seq_id``'s fresh chain and return the number
        of tokens covered (0 on a miss).  The hit is capped one token short
        of ``len(tokens)`` — at least the final prompt token is always
        re-prefilled so the forward produces the logits row the first
        sampled token comes from."""
        pc = self.prefix
        if pc is None or len(tokens) == 0:
            return 0
        bs = self.pool.block_size
        pc.lookup_tokens += len(tokens)
        cap = (len(tokens) - 1) // bs       # never cover the whole prompt
        blocks: List[int] = []
        for h in prefix_block_hashes(tokens, bs)[:cap]:
            b = pc.get(h)
            if b is None:
                break
            blocks.append(b)
        if not blocks:
            pc.misses += 1
            return 0
        self.pool.share_prefix(seq_id, blocks)
        pc.hits += 1
        pc.hit_tokens += len(blocks) * bs
        return len(blocks) * bs

    def register_prefix(self, seq_id: int, tokens) -> int:
        """Claim ``seq_id``'s fully-written prompt blocks for future lookups:
        every full block of ``tokens`` the chain already covers gets its
        chain hash registered (first claim wins; a hash someone else already
        owns leaves this chain's copy private).  Returns new claims made."""
        pc = self.prefix
        if pc is None:
            return 0
        bs = self.pool.block_size
        table = self.pool.block_table(seq_id)
        n_full = min(len(tokens) // bs, self.pool.length(seq_id) // bs,
                     len(table))
        claimed = 0
        for i, h in enumerate(prefix_block_hashes(tokens, bs)[:n_full]):
            if pc.claim(h, table[i]):
                claimed += 1
        if claimed:
            self.pool.trace.instant("prefix_register", track="pool",
                                    cat="cache", seq=seq_id, blocks=claimed)
        return claimed

    def prepare_write(self, seq_id: int, start: int, end: int) -> None:
        """Copy-on-write barrier for an upcoming scatter into positions
        ``[start, end)`` of ``seq_id``'s chain (no-op without sharing)."""
        if self.prefix is not None:
            self.pool.make_private(seq_id, start, end)

    # -- admission ----------------------------------------------------------
    @property
    def reserved_blocks(self) -> int:
        """Watermark: worst-case blocks still owed to registered residents."""
        return sum(max(0, w - len(self.pool.block_table(sid)))
                   for sid, w in self._resident_worst.items())

    def can_admit(self, first_alloc_tokens: int, worst_case_blocks: int) -> bool:
        if self.policy == "watermark":
            # LRU-retained prefix blocks count as free: growth reclaims them
            # on demand, so the reservation guarantee still holds
            retained = self.prefix.num_retained if self.prefix else 0
            return (self.pool.allocator.num_free + retained
                    - self.reserved_blocks >= worst_case_blocks)
        return self.pool.can_fit(first_alloc_tokens)

    def register(self, seq_id: int, worst_case_blocks: int) -> None:
        """Mark ``seq_id`` resident (watermark accounting input)."""
        self._resident_worst[seq_id] = worst_case_blocks

    # -- growth / release ---------------------------------------------------
    def grow(self, seq_id: int, length: int) -> None:
        """Grow ``seq_id`` to ``length`` tokens; raises ``OutOfBlocks`` when
        the pool is exhausted (the scheduler then preempts)."""
        self.pool.ensure_capacity(seq_id, length)

    def release(self, seq_id: int) -> None:
        """Retire or evict: free the chain and drop residency."""
        self.pool.free_seq(seq_id)
        self._resident_worst.pop(seq_id, None)

    def truncate(self, seq_id: int, length: int) -> None:
        """Roll ``seq_id`` back to ``length`` tokens (rejected speculative
        verify-window tail): exclusively-owned tail blocks return to the free
        list immediately, while a tail block another chain still references
        is only un-linked (its content is never rolled back under the other
        resident); residency is kept — the watermark reservation grows back
        by exactly the released blocks, so both admission policies stay
        conserved."""
        self.pool.truncate(seq_id, length)

    # -- eviction -----------------------------------------------------------
    def preempt_recompute(self, seq_id: int) -> None:
        self.release(seq_id)
        self.preemptions += 1

    def preempt_swap_out(self, seq_id: int, length: int) -> Optional[SwappedSeq]:
        """Copy ``length`` cached tokens to host, then free the chain.
        ``length`` comes from the *request's* state, not ``pool.length`` —
        a growth bump whose decode step never ran must not be swapped.
        Returns None when nothing is cached yet (plain requeue)."""
        self.preemptions += 1
        if length <= 0:
            self.release(seq_id)
            return None
        with self.pool.trace.span("swap_out", track="pool", cat="swap",
                                  seq=seq_id, length=length):
            # gather the victim's slots on device, then transfer just those —
            # host traffic is O(sequence), not O(pool).  Block-summary leaves
            # index blocks, not slots: their chain rows travel separately.
            slots = jnp.asarray(self.pool.flat_slots(seq_id, np.arange(length)))
            chain = jnp.asarray(
                self.pool.block_table(seq_id)[:-(-length // self.pool.block_size)],
                jnp.int32)
            streams = {p_key: {name: np.asarray(arr[:, slots])
                               for name, arr in layer.items()
                               if not is_block_summary(name)}
                       for p_key, layer in self.pool.pages.items()}
            block_streams = {
                p_key: {name: np.asarray(arr[:, chain])
                        for name, arr in layer.items()
                        if is_block_summary(name)}
                for p_key, layer in self.pool.pages.items()}
            self.release(seq_id)
            swapped = SwappedSeq(length=length, streams=streams,
                                 block_streams=block_streams)
        self.swap_outs += 1
        self.swapped_bytes += swapped.nbytes()
        return swapped

    def swap_in(self, seq_id: int, swapped: SwappedSeq) -> None:
        """Allocate a fresh chain and scatter the host copy back.  Raises
        ``OutOfBlocks`` if the prefix does not fit (caller defers admission)."""
        self.pool.ensure_capacity(seq_id, swapped.length)
        with self.pool.trace.span("swap_in", track="pool", cat="swap",
                                  seq=seq_id, length=swapped.length):
            slots = jnp.asarray(self.pool.flat_slots(seq_id,
                                                     np.arange(swapped.length)))
            for p_key, layer in swapped.streams.items():
                self.pool.pages[p_key] = {
                    **self.pool.pages[p_key],
                    **{name: self.pool.pages[p_key][name].at[:, slots].set(
                        jnp.asarray(host, self.pool.pages[p_key][name].dtype))
                       for name, host in layer.items()}}
            if swapped.block_streams:
                chain = jnp.asarray(
                    self.pool.block_table(seq_id)[
                        :-(-swapped.length // self.pool.block_size)],
                    jnp.int32)
                for p_key, layer in swapped.block_streams.items():
                    self.pool.pages[p_key] = {
                        **self.pool.pages[p_key],
                        **{name: self.pool.pages[p_key][name]
                            .at[:, chain].set(jnp.asarray(host))
                           for name, host in layer.items()}}
        self.swap_ins += 1


def measured_cache_bytes(cache, batch: int, max_len: int) -> Dict[str, int]:
    """Actual bytes in a live cache pytree, split attn vs ssm."""
    attn = ssm = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache["blocks"]):
        name = jax.tree_util.keystr(path)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if "conv" in name or "ssm" in name:
            ssm += nbytes
        else:
            attn += nbytes
    return {"attn_bytes": attn, "ssm_bytes": ssm,
            "attn_bytes_per_token": attn // (batch * max_len)}
