"""KV-cache size accounting + the block-paged compressed-KV pool.

Size formulas (paper §3.2), per token per attention layer, in floats:
    vanilla MHA/GQA:      2 · n_kv · d_h
    RoPElite + J-LRD:     2 · r · n_kv + d_ckv
    RoPElite + S-LRD:     2 · r · n_kv + d_ck + d_cv
Mamba layers hold O(1) state instead (conv + ssm), reported separately.

Paged pool
----------
``PagedKVPool`` stores the compressed ``(k_e, c_kv)`` streams of every
attention layer in fixed-size token *blocks* shared across sequences
(vLLM-style).  Sequences own ragged chains of blocks via per-sequence block
tables; chains grow one block at a time on demand and recycle the moment a
sequence retires.  Device pages are plain jax arrays handed to jitted steps
and reassigned; all bookkeeping (free list, tables, lengths) is host-side
Python.

``BlockManager`` layers the serving scheduler's *policy* on top of the pool:
admission gating (preempt-on-demand vs the legacy watermark reservation),
resident registration, and the two eviction mechanisms — recompute (free the
victim's blocks; the scheduler re-prefills its prefix later) and host
swap-out (copy the victim's cached streams to host memory and restore them
block-exactly on re-admission).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.obs.trace import NULL_TRACER


def attn_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.elitekv.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)


def model_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.n_attn_layers * attn_cache_floats_per_token(cfg)


def ssm_state_floats(cfg: ModelConfig, batch: int) -> int:
    n_ssm = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "ssm")
    per = (cfg.ssm_conv - 1) * cfg.d_inner + cfg.d_inner * cfg.ssm_state
    return n_ssm * per * batch


def cache_ratio(cfg_elite: ModelConfig, cfg_base: ModelConfig) -> float:
    """Attention-KV compression ratio vs the unmodified model."""
    a = model_cache_floats_per_token(cfg_elite)
    b = model_cache_floats_per_token(cfg_base)
    return a / b if b else 1.0


class OutOfBlocks(RuntimeError):
    """Raised when the pool cannot satisfy an allocation (caller may retry
    after retiring sequences, or refuse admission)."""


class BlockAllocator:
    """Host-side free-list over ``num_blocks`` fixed-size token blocks."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.high_water = 0          # max blocks simultaneously in use
        self.total_allocs = 0        # lifetime alloc count (reuse visibility)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        got = [self._free.pop() for _ in range(n)]
        self.total_allocs += n
        self.high_water = max(self.high_water, self.num_used)
        return got

    def free(self, blocks: Sequence[int]) -> None:
        self._free.extend(blocks)

    def reset(self) -> None:
        self._free = list(range(self.num_blocks - 1, -1, -1))


@dataclasses.dataclass
class PoolStats:
    block_size: int
    num_blocks: int
    blocks_in_use: int
    blocks_free: int
    high_water_blocks: int
    total_allocs: int
    live_tokens: int        # sum of sequence lengths
    allocated_tokens: int   # blocks_in_use * block_size (internal fragmentation)
    live_bytes: int
    allocated_bytes: int


class PagedKVPool:
    """Block-paged device storage for EliteKV's compressed cache streams.

    Pages mirror ``lm.init_cache``'s per-``p_pos`` layout but replace the
    ``[B, max_len, ...]`` leading dims with one flat ``[n_slots, ...]`` token
    axis (``n_slots = num_blocks · block_size``); token ``t`` of block ``b``
    lives at flat slot ``b · block_size + t``.  Only attention layers page —
    serving currently requires an attention-only, EliteKV-enabled config
    (Mamba's O(1) state needs no paging; hybrid support is a ROADMAP item).
    """

    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int,
                 dtype=jnp.float32, tracer=None):
        assert cfg.elitekv.enabled, "paged pool stores compressed streams only"
        self.trace = tracer or NULL_TRACER   # obs: alloc/free/truncate events
        for p_pos in range(cfg.block_period):
            assert cfg.layer_kind(p_pos) == "attn", \
                "paged serving supports attention-only stacks (see ROADMAP)"
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.dtype = dtype
        self.allocator = BlockAllocator(num_blocks)
        self._tables: Dict[int, List[int]] = {}   # seq_id → block chain
        self._lengths: Dict[int, int] = {}        # seq_id → live token count
        e = cfg.elitekv
        n_super = cfg.num_layers // cfg.block_period
        n_slots = num_blocks * block_size
        r2 = 2 * e.elite_r

        def _streams():
            s = {"k_e": jnp.zeros((n_super, n_slots, cfg.n_kv_heads, r2), dtype)}
            if e.lrd == "joint":
                s["c"] = jnp.zeros((n_super, n_slots, e.d_ckv), dtype)
            else:
                s["c_k"] = jnp.zeros((n_super, n_slots, e.d_ck), dtype)
                s["c_v"] = jnp.zeros((n_super, n_slots, e.d_cv), dtype)
            return s

        self.pages = {f"p{p}": _streams() for p in range(cfg.block_period)}

    # -- sequence lifecycle -------------------------------------------------
    def ensure_capacity(self, seq_id: int, length: int) -> None:
        """Grow ``seq_id``'s block chain to hold ``length`` tokens (allocates
        lazily on first touch).  Raises OutOfBlocks when the pool is full."""
        table = self._tables.setdefault(seq_id, [])
        need = -(-length // self.block_size) - len(table)
        if need > 0:
            got = self.allocator.alloc(need)
            table.extend(got)
            self.trace.instant("alloc", track="pool", cat="pool", seq=seq_id,
                               blocks=got, length=length)
        self._lengths[seq_id] = max(self._lengths.get(seq_id, 0), length)

    def can_fit(self, extra_tokens: int) -> bool:
        return self.allocator.num_free * self.block_size >= extra_tokens

    def truncate(self, seq_id: int, length: int) -> None:
        """Shrink ``seq_id`` to ``length`` tokens, freeing tail blocks the
        shorter chain no longer covers (speculative decode rolls rejected
        verify-window tokens back through here — pages are never rewritten,
        the stale slots are simply re-extended over by later growth).
        ``length`` must not exceed the current length; 0 keeps the (empty)
        chain registered."""
        assert length >= 0, length
        if seq_id not in self._lengths:     # unknown/freed seq: only the
            assert length == 0, (seq_id, length)   # no-op shrink is legal,
            return                          # and it must not register one
        cur = self._lengths[seq_id]
        assert length <= cur, (seq_id, length, cur)
        table = self._tables.get(seq_id, [])
        keep = -(-length // self.block_size)
        if keep < len(table):
            freed = table[keep:]
            self.allocator.free(freed)
            del table[keep:]
            self.trace.instant("free", track="pool", cat="pool", seq=seq_id,
                               blocks=freed, reason="truncate", length=length)
        self._lengths[seq_id] = length

    def free_seq(self, seq_id: int) -> None:
        blocks = self._tables.pop(seq_id, [])
        if blocks:
            self.trace.instant("free", track="pool", cat="pool", seq=seq_id,
                               blocks=blocks, reason="release")
        self.allocator.free(blocks)
        self._lengths.pop(seq_id, None)

    def reset(self) -> None:
        self.allocator.reset()
        self._tables.clear()
        self._lengths.clear()

    def length(self, seq_id: int) -> int:
        return self._lengths.get(seq_id, 0)

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._tables.get(seq_id, []))

    # -- device-side index helpers -----------------------------------------
    @property
    def oob_slot(self) -> int:
        """Scatter sentinel: one past the last flat slot (dropped by
        ``mode="drop"`` writes — used to mask inactive batch lanes)."""
        return self.num_blocks * self.block_size

    def block_table_array(self, seq_ids: Sequence[Optional[int]],
                          max_blocks: int) -> np.ndarray:
        """Padded int32 ``[len(seq_ids), max_blocks]`` table (pad = block 0;
        padded pages are masked out by per-sequence lengths downstream)."""
        out = np.zeros((len(seq_ids), max_blocks), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            t = self._tables.get(sid, [])
            assert len(t) <= max_blocks, (len(t), max_blocks)
            out[i, :len(t)] = t
        return out

    def flat_slots(self, seq_id: int, positions) -> np.ndarray:
        """Flat pool slots for logical ``positions`` of ``seq_id``'s chain:
        position ``p`` lives at ``table[p // bs] · bs + p % bs``.  The single
        source of the slot-layout formula (decode/prefill mappings and host
        swap all route through here)."""
        table = np.asarray(self._tables[seq_id], np.int64)
        pos = np.asarray(positions)
        return table[pos // self.block_size] * self.block_size \
            + pos % self.block_size

    def slot_mapping(self, seq_ids: Sequence[Optional[int]],
                     positions: Sequence[int]) -> np.ndarray:
        """Flat write slots for one token per sequence; inactive lanes
        (seq_id None) map to ``oob_slot``."""
        out = np.full((len(seq_ids),), self.oob_slot, np.int32)
        for i, (sid, pos) in enumerate(zip(seq_ids, positions)):
            if sid is not None:
                out[i] = self.flat_slots(sid, pos)
        return out

    def prefill_slot_mapping(self, seq_id: int, start: int,
                             n_tokens: int, pad_to: int) -> np.ndarray:
        """Flat write slots for ``n_tokens`` consecutive positions starting at
        ``start``, padded with ``oob_slot`` up to ``pad_to`` (prompt padding)."""
        out = np.full((pad_to,), self.oob_slot, np.int32)
        out[:n_tokens] = self.flat_slots(seq_id,
                                         np.arange(start, start + n_tokens))
        return out

    # -- accounting ---------------------------------------------------------
    def floats_per_token(self) -> int:
        return model_cache_floats_per_token(self.cfg)

    def stats(self) -> PoolStats:
        itemsize = jnp.dtype(self.dtype).itemsize
        live = sum(self._lengths.values())
        alloc_tok = self.allocator.num_used * self.block_size
        fpt = self.floats_per_token()
        return PoolStats(
            block_size=self.block_size, num_blocks=self.num_blocks,
            blocks_in_use=self.allocator.num_used,
            blocks_free=self.allocator.num_free,
            high_water_blocks=self.allocator.high_water,
            total_allocs=self.allocator.total_allocs,
            live_tokens=live, allocated_tokens=alloc_tok,
            live_bytes=live * fpt * itemsize,
            allocated_bytes=alloc_tok * fpt * itemsize)


@dataclasses.dataclass
class SwappedSeq:
    """Host-side copy of a preempted sequence's cached streams (swap
    eviction).  ``streams[p_key][name]`` is a ``[n_super, length, ...]``
    numpy array in *token order* — independent of which physical blocks the
    sequence owned, so swap-in may land on a completely different chain."""
    length: int
    streams: Dict[str, Dict[str, np.ndarray]]

    def nbytes(self) -> int:
        return sum(a.nbytes for s in self.streams.values() for a in s.values())


class BlockManager:
    """Admission + eviction policy over a ``PagedKVPool``.

    Two admission policies:

    * ``"preempt"`` (default) — no reservation.  A request is admitted as
      soon as its *next allocation* (first prefill chunk, or the swapped-out
      prefix being restored) fits in the free list; residents grow blocks on
      demand and growth may raise ``OutOfBlocks`` mid-flight, which the
      scheduler resolves by preempting the youngest resident.
    * ``"watermark"`` — the legacy reservation policy: the worst-case blocks
      still owed to every registered resident are held back, so admission is
      refused unless the newcomer's full worst case fits in
      ``free − reserved`` and growth can never fail.

    Eviction mechanisms (used by the scheduler's preemption path):

    * ``preempt_recompute`` — drop the victim's blocks; its cached prefix is
      rebuilt by a recompute-prefill after re-admission.  Cheap to evict,
      costs one prefill of the prefix — and under EliteKV that prefill only
      re-fills the low-rank ``(k_e, c_kv)`` streams, the paper's compression
      making recompute proportionally cheaper than for a full KV cache.
    * ``preempt_swap_out`` / ``swap_in`` — copy the victim's live tokens to
      host memory, free the blocks, and scatter the copy back into a fresh
      chain on re-admission.  Costs PCIe traffic instead of FLOPs.
    """

    def __init__(self, pool: PagedKVPool, policy: str = "preempt"):
        assert policy in ("preempt", "watermark"), policy
        self.pool = pool
        self.policy = policy
        self._resident_worst: Dict[int, int] = {}   # seq_id → worst-case blocks
        self.preemptions = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_bytes = 0                      # lifetime host-swap traffic

    # -- admission ----------------------------------------------------------
    @property
    def reserved_blocks(self) -> int:
        """Watermark: worst-case blocks still owed to registered residents."""
        return sum(max(0, w - len(self.pool.block_table(sid)))
                   for sid, w in self._resident_worst.items())

    def can_admit(self, first_alloc_tokens: int, worst_case_blocks: int) -> bool:
        if self.policy == "watermark":
            return (self.pool.allocator.num_free - self.reserved_blocks
                    >= worst_case_blocks)
        return self.pool.can_fit(first_alloc_tokens)

    def register(self, seq_id: int, worst_case_blocks: int) -> None:
        """Mark ``seq_id`` resident (watermark accounting input)."""
        self._resident_worst[seq_id] = worst_case_blocks

    # -- growth / release ---------------------------------------------------
    def grow(self, seq_id: int, length: int) -> None:
        """Grow ``seq_id`` to ``length`` tokens; raises ``OutOfBlocks`` when
        the pool is exhausted (the scheduler then preempts)."""
        self.pool.ensure_capacity(seq_id, length)

    def release(self, seq_id: int) -> None:
        """Retire or evict: free the chain and drop residency."""
        self.pool.free_seq(seq_id)
        self._resident_worst.pop(seq_id, None)

    def truncate(self, seq_id: int, length: int) -> None:
        """Roll ``seq_id`` back to ``length`` tokens (rejected speculative
        verify-window tail): tail blocks return to the free list immediately,
        residency is kept — the watermark reservation grows back by exactly
        the freed blocks, so both admission policies stay conserved."""
        self.pool.truncate(seq_id, length)

    # -- eviction -----------------------------------------------------------
    def preempt_recompute(self, seq_id: int) -> None:
        self.release(seq_id)
        self.preemptions += 1

    def preempt_swap_out(self, seq_id: int, length: int) -> Optional[SwappedSeq]:
        """Copy ``length`` cached tokens to host, then free the chain.
        ``length`` comes from the *request's* state, not ``pool.length`` —
        a growth bump whose decode step never ran must not be swapped.
        Returns None when nothing is cached yet (plain requeue)."""
        self.preemptions += 1
        if length <= 0:
            self.release(seq_id)
            return None
        with self.pool.trace.span("swap_out", track="pool", cat="swap",
                                  seq=seq_id, length=length):
            # gather the victim's slots on device, then transfer just those —
            # host traffic is O(sequence), not O(pool)
            slots = jnp.asarray(self.pool.flat_slots(seq_id, np.arange(length)))
            streams = {p_key: {name: np.asarray(arr[:, slots])
                               for name, arr in layer.items()}
                       for p_key, layer in self.pool.pages.items()}
            self.release(seq_id)
            swapped = SwappedSeq(length=length, streams=streams)
        self.swap_outs += 1
        self.swapped_bytes += swapped.nbytes()
        return swapped

    def swap_in(self, seq_id: int, swapped: SwappedSeq) -> None:
        """Allocate a fresh chain and scatter the host copy back.  Raises
        ``OutOfBlocks`` if the prefix does not fit (caller defers admission)."""
        self.pool.ensure_capacity(seq_id, swapped.length)
        with self.pool.trace.span("swap_in", track="pool", cat="swap",
                                  seq=seq_id, length=swapped.length):
            slots = jnp.asarray(self.pool.flat_slots(seq_id,
                                                     np.arange(swapped.length)))
            for p_key, layer in swapped.streams.items():
                self.pool.pages[p_key] = {
                    name: self.pool.pages[p_key][name].at[:, slots].set(
                        jnp.asarray(host, self.pool.pages[p_key][name].dtype))
                    for name, host in layer.items()}
        self.swap_ins += 1


def measured_cache_bytes(cache, batch: int, max_len: int) -> Dict[str, int]:
    """Actual bytes in a live cache pytree, split attn vs ssm."""
    attn = ssm = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache["blocks"]):
        name = jax.tree_util.keystr(path)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if "conv" in name or "ssm" in name:
            ssm += nbytes
        else:
            attn += nbytes
    return {"attn_bytes": attn, "ssm_bytes": ssm,
            "attn_bytes_per_token": attn // (batch * max_len)}
