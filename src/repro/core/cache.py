"""KV-cache size accounting — the quantity the paper optimizes.

Formulas (paper §3.2), per token per attention layer, in floats:
    vanilla MHA/GQA:      2 · n_kv · d_h
    RoPElite + J-LRD:     2 · r · n_kv + d_ckv
    RoPElite + S-LRD:     2 · r · n_kv + d_ck + d_cv
Mamba layers hold O(1) state instead (conv + ssm), reported separately.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig


def attn_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.elitekv.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)


def model_cache_floats_per_token(cfg: ModelConfig) -> int:
    return cfg.n_attn_layers * attn_cache_floats_per_token(cfg)


def ssm_state_floats(cfg: ModelConfig, batch: int) -> int:
    n_ssm = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "ssm")
    per = (cfg.ssm_conv - 1) * cfg.d_inner + cfg.d_inner * cfg.ssm_state
    return n_ssm * per * batch


def cache_ratio(cfg_elite: ModelConfig, cfg_base: ModelConfig) -> float:
    """Attention-KV compression ratio vs the unmodified model."""
    a = model_cache_floats_per_token(cfg_elite)
    b = model_cache_floats_per_token(cfg_base)
    return a / b if b else 1.0


def measured_cache_bytes(cache, batch: int, max_len: int) -> Dict[str, int]:
    """Actual bytes in a live cache pytree, split attn vs ssm."""
    attn = ssm = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache["blocks"]):
        name = jax.tree_util.keystr(path)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if "conv" in name or "ssm" in name:
            ssm += nbytes
        else:
            attn += nbytes
    return {"attn_bytes": attn, "ssm_bytes": ssm,
            "attn_bytes_per_token": attn // (batch * max_len)}
