"""Model surgery: baseline GQA/MHA checkpoint → EliteKV checkpoint.

Steps per attention layer (paper §3 pipeline):
  1. RoPElite search gives elite chunk indices per KV head (greedy order).
  2. Permute W^q / W^k columns per head so elite chunks occupy dims [0, 2r)
     — query heads use their KV group's elite order (keys are shared).
  3. Slice W^k into the elite part (kept dense, rotated at runtime) and the
     non-elite remainder; J-LRD (or S-LRD) factorize [W^k_ne , W^v].
  4. Store the elite theta values as a non-trainable buffer.

Also provides the *GQA mean-pool* conversion (Ainslie et al. 2023) — the
paper's comparison baseline — and EliteKV dimension selection helpers
(paper App. C: 128-aligned d_ckv, no-extra-parameter rule).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EliteKVConfig, ModelConfig
from repro.core import lrd as lrd_lib
from repro.core import rope as rope_lib


def _perm_for(elite_idx: np.ndarray, C: int) -> np.ndarray:
    """Dim permutation [d_h] putting elite chunk pairs first (greedy order)."""
    elite = [int(c) for c in elite_idx]
    rest = [c for c in range(C) if c not in elite]
    dims = []
    for c in elite + rest:
        dims += [2 * c, 2 * c + 1]
    return np.asarray(dims, np.int32)


def convert_layer(attn_params: Dict, cfg: ModelConfig, e: EliteKVConfig,
                  elite_idx: jnp.ndarray) -> Tuple[Dict, Dict]:
    """One attention layer → (elite params, buffers)."""
    dh, nkv, nh = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads
    C = dh // 2
    r = e.elite_r
    r2 = 2 * r
    elite_idx = np.asarray(elite_idx)
    assert elite_idx.shape == (nkv, r)

    wq = np.asarray(attn_params["wq"])    # [d, nh, dh]
    wk = np.asarray(attn_params["wk"])    # [d, nkv, dh]
    wv = np.asarray(attn_params["wv"])    # [d, nkv, dh]

    wq_p = np.empty_like(wq)
    wk_p = np.empty_like(wk)
    G = cfg.q_group
    for h_kv in range(nkv):
        perm = _perm_for(elite_idx[h_kv], C)
        wk_p[:, h_kv, :] = wk[:, h_kv, perm]
        for g in range(G):
            hq = h_kv * G + g
            wq_p[:, hq, :] = wq[:, hq, perm]

    wk_e = wk_p[:, :, :r2]
    wk_ne = wk_p[:, :, r2:]

    params = {
        "wq": jnp.asarray(wq_p, jnp.float32),
        "wk_e": jnp.asarray(wk_e, jnp.float32),
        "wo": jnp.asarray(attn_params["wo"], jnp.float32),
    }
    if e.lrd == "joint":
        a_kv, bk, bv = lrd_lib.jlrd(wk_ne, wv, e.d_ckv)
        params["a_kv"], params["bk"], params["bv"] = a_kv, jnp.asarray(bk), jnp.asarray(bv)
    else:
        a_k, a_v, bk, bv = lrd_lib.slrd(jnp.asarray(wk_ne), jnp.asarray(wv), e.d_ck, e.d_cv)
        params["a_k"], params["a_v"] = a_k, a_v
        params["bk"], params["bv"] = jnp.asarray(bk), jnp.asarray(bv)

    freqs = np.asarray(rope_lib.chunk_freqs(dh, cfg.rope_theta))
    buffers = {"elite_freqs": jnp.asarray(freqs[elite_idx], jnp.float32)}
    return params, buffers


def convert_model(params: Dict, buffers: Dict, cfg: ModelConfig,
                  elite_sets: Dict[int, jnp.ndarray],
                  elitekv: EliteKVConfig) -> Tuple[Dict, Dict, ModelConfig]:
    """Whole-model conversion.  ``elite_sets``: {abs layer idx: [nkv, r]}."""
    assert not cfg.elitekv.enabled
    new_cfg = dataclasses.replace(
        cfg, elitekv=dataclasses.replace(elitekv, enabled=True))
    P_ = cfg.block_period
    new_params = {k: v for k, v in params.items() if k != "blocks"}
    new_blocks = {}
    new_buf_blocks = {}
    for p_key, blk in params["blocks"].items():
        p_pos = int(p_key[1:])
        if cfg.layer_kind(p_pos) != "attn":
            new_blocks[p_key] = blk
            new_buf_blocks[p_key] = buffers["blocks"].get(p_key, {})
            continue
        n_super = jax.tree.leaves(blk)[0].shape[0]
        per_layer_p, per_layer_b = [], []
        for s in range(n_super):
            li = s * P_ + p_pos
            attn_s = jax.tree.map(lambda t: t[s], blk["attn"])
            pe, be = convert_layer(attn_s, cfg, elitekv, elite_sets[li])
            per_layer_p.append(pe)
            per_layer_b.append(be)
        stacked_attn = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer_p)
        stacked_buf = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer_b)
        nb = {k: v for k, v in blk.items() if k != "attn"}
        nb["attn"] = stacked_attn
        new_blocks[p_key] = nb
        new_buf_blocks[p_key] = stacked_buf
    new_params["blocks"] = new_blocks
    return new_params, {"blocks": new_buf_blocks}, new_cfg


def elitekv_from_baseline(params, buffers, cfg, calib_batch, elitekv: EliteKVConfig,
                          method: str = "greedy", moe_impl: str = "dense"):
    """Search + convert in one call (the paper's full §3 pipeline)."""
    from repro.core import ropelite
    sets = ropelite.search_model(params, buffers, cfg, calib_batch,
                                 elitekv.elite_r, method=method, moe_impl=moe_impl)
    return convert_model(params, buffers, cfg, sets, elitekv)


# ---------------------------------------------------------------------------
# GQA mean-pool baseline (Ainslie et al.) — paper's comparison point
# ---------------------------------------------------------------------------

def to_gqa(params: Dict, cfg: ModelConfig, new_n_kv: int) -> Tuple[Dict, ModelConfig]:
    assert cfg.n_kv_heads % new_n_kv == 0
    m = cfg.n_kv_heads // new_n_kv
    new_cfg = dataclasses.replace(cfg, n_kv_heads=new_n_kv)

    def pool(w):  # [n_super, d, nkv, dh] → mean over groups of m kv heads
        ns, d, nkv, dh = w.shape
        return w.reshape(ns, d, new_n_kv, m, dh).mean(axis=3)

    new_params = {k: v for k, v in params.items() if k != "blocks"}
    new_blocks = {}
    for p_key, blk in params["blocks"].items():
        p_pos = int(p_key[1:])
        if cfg.layer_kind(p_pos) != "attn" or "wk" not in blk.get("attn", {}):
            new_blocks[p_key] = blk
            continue
        nb = dict(blk)
        attn = dict(blk["attn"])
        attn["wk"] = pool(blk["attn"]["wk"])
        attn["wv"] = pool(blk["attn"]["wv"])
        nb["attn"] = attn
        new_blocks[p_key] = nb
    new_params["blocks"] = new_blocks
    return new_params, new_cfg


# ---------------------------------------------------------------------------
# dimension selection (paper App. C)
# ---------------------------------------------------------------------------

def pick_dims(cfg: ModelConfig, target_cache_ratio: float, align: int = 128,
              r_candidates=(2, 4, 8, 16, 32)) -> EliteKVConfig:
    """Choose (r, d_ckv) hitting a target cache ratio.

    Rules (App. C): d_ckv MXU-aligned (128 preferred; falls back 64/32/16 for
    GQA archs whose whole cache budget is below 128 — the paper's MHA models
    never hit this); no parameter increase vs baseline; among valid configs
    prefer closest ratio, then the largest r (more rotary signal).
    """
    dh, nkv, nh, d = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads, cfg.d_model
    full = 2 * nkv * dh
    base_params = d * dh * 2 * nkv          # W^k + W^v
    best = None
    for r in sorted(r_candidates, reverse=True):
        if 2 * r >= dh:
            continue
        budget = int(target_cache_ratio * full) - 2 * r * nkv
        d_ckv = 0
        for a in (align, 64, 32, 16):
            if (budget // a) * a >= a:
                d_ckv = (budget // a) * a
                break
        if d_ckv <= 0:
            continue
        d_nope = dh - 2 * r
        new_params = (d * 2 * r * nkv                       # W^k elite
                      + d * d_ckv                           # A^kv
                      + d_ckv * (nkv * d_nope + nkv * dh))  # B^k, B^v
        if new_params > base_params:
            continue
        got = (2 * r * nkv + d_ckv) / full
        cand = EliteKVConfig(enabled=True, elite_r=r, d_ckv=d_ckv, lrd="joint")
        if best is None or abs(got - target_cache_ratio) < best[0] - 1e-9:
            best = (abs(got - target_cache_ratio), cand)
    if best is None:
        raise ValueError(f"no valid EliteKV dims for ratio {target_cache_ratio}")
    return best[1]
