"""EliteKV attention: RoPElite partial rotation + joint low-rank KV latent.

Weight layout (after conversion from a baseline GQA/MHA checkpoint, or direct
init for from-scratch training):

  wq    [d, n_h, d_h]     — query projection, columns permuted per head so that
                            dims [0:2r) are that head's KV-group elite chunks
                            (in greedy-selection order) and [2r:) the non-elite.
  wk_e  [d, n_kv, 2r]     — elite key slice (rotated with per-head elite freqs).
  a_kv  [d, d_ckv]        — J-LRD shared down-projection  (or a_k/a_v for S-LRD).
  bk    [d_c, n_kv, d_h-2r] — K up-projection  (latent → non-elite key dims).
  bv    [d_c, n_kv, d_h]  — V up-projection.
  wo    [n_h, d_h, d]     — output projection (unchanged).

Buffers (non-trainable): ``elite_freqs`` [n_kv, r] — theta values of the elite
chunks, in the order the greedy search picked them.

Cache per token per layer (paper §3.2):  2·r·n_kv  (rotated elite keys, stored
POST-rotation — never re-rotated at decode)  +  d_ckv  (shared latent).

Decode uses MLA-style *absorption at the activation level*:
    q_ne · k_neᵀ = q_ne · (c·bk)ᵀ = (q_ne·bkᵀ) · cᵀ        (bk absorbed into q)
    o = p · v = p · (c·bv) = (p·c) · bv                     (bv absorbed into o)
so only the compressed cache is ever read — the paper's systems win.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core import rope as rope_lib
from repro.models.attention import causal_mask


# ---------------------------------------------------------------------------
# init (from scratch; convert.py builds these from a baseline checkpoint)
# ---------------------------------------------------------------------------

def init(key, cfg) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (params, buffers)."""
    from repro.models.layers import dense_init
    d, dh, nh, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    e = cfg.elitekv
    r2 = 2 * e.elite_r
    d_nope = dh - r2
    ks = jax.random.split(key, 8)
    params = {
        "wq": dense_init(ks[0], (d, nh, dh)),
        "wk_e": dense_init(ks[1], (d, nkv, r2)),
        "wo": dense_init(ks[2], (nh, dh, d), in_axis=2, scale=(nh * dh) ** -0.5),
    }
    if e.lrd == "joint":
        params["a_kv"] = dense_init(ks[3], (d, e.d_ckv))
        params["bk"] = dense_init(ks[4], (e.d_ckv, nkv, d_nope), scale=e.d_ckv ** -0.5)
        params["bv"] = dense_init(ks[5], (e.d_ckv, nkv, dh), scale=e.d_ckv ** -0.5)
    else:
        params["a_k"] = dense_init(ks[3], (d, e.d_ck))
        params["a_v"] = dense_init(ks[6], (d, e.d_cv))
        params["bk"] = dense_init(ks[4], (e.d_ck, nkv, d_nope), scale=e.d_ck ** -0.5)
        params["bv"] = dense_init(ks[5], (e.d_cv, nkv, dh), scale=e.d_cv ** -0.5)
    # default elite chunks: top-r highest frequencies (uniform init; the real
    # sets come from the RoPElite search at conversion time).
    freqs = rope_lib.chunk_freqs(dh, cfg.rope_theta)
    buffers = {"elite_freqs": jnp.tile(freqs[None, :e.elite_r], (nkv, 1))}
    return params, buffers


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _project_q(params, cfg, x, positions):
    """Returns rotated q_e [B,S,nh,2r] and linear q_ne [B,S,nh,d_nope]."""
    dt = x.dtype
    e = cfg.elitekv
    r2 = 2 * e.elite_r
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    q_e, q_ne = q[..., :r2], q[..., r2:]
    return q_e, q_ne


def _rot_q(cfg, buffers, q_e, positions):
    ef_q = rope_lib.expand_kv_to_q(buffers["elite_freqs"], cfg.q_group)  # [nh, r]
    return rope_lib.apply_elite_rope(q_e, positions, ef_q)


def _latents(params, cfg, x):
    """Down-projected latent(s): (c_k, c_v) — identical object for J-LRD."""
    dt = x.dtype
    if cfg.elitekv.lrd == "joint":
        c = x @ params["a_kv"].astype(dt)
        return c, c
    return x @ params["a_k"].astype(dt), x @ params["a_v"].astype(dt)


# ---------------------------------------------------------------------------
# full-sequence (training / prefill): materialized K,V
# ---------------------------------------------------------------------------

def _materialized(params, cfg, buffers, x, positions, constrain=lambda n, t: t):
    dt = x.dtype
    e = cfg.elitekv
    q_e, q_ne = _project_q(params, cfg, x, positions)
    q_e = _rot_q(cfg, buffers, q_e, positions)
    k_e = jnp.einsum("bsd,dhe->bshe", x, params["wk_e"].astype(dt))
    k_e = rope_lib.apply_elite_rope(k_e, positions, buffers["elite_freqs"])
    c_k, c_v = _latents(params, cfg, x)
    c_k, c_v = constrain("latent", c_k), constrain("latent", c_v)
    k_ne = jnp.einsum("bsc,che->bshe", c_k, params["bk"].astype(dt))
    v = constrain("attn_kv", jnp.einsum("bsc,che->bshe", c_v, params["bv"].astype(dt)))
    q = constrain("attn_q", jnp.concatenate([q_e, q_ne], axis=-1))
    k = constrain("attn_kv", jnp.concatenate([k_e, k_ne], axis=-1))
    return q, k, v, k_e, c_k, c_v


def apply_full(params, cfg, buffers, x, positions, constrain=lambda n, t: t) -> jnp.ndarray:
    from repro.models.attention import _attend
    q, k, v, *_ = _materialized(params, cfg, buffers, x, positions, constrain)
    o = _attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5,
                chunk_q=cfg.attn_chunk_q, constrain=constrain,
                unroll=cfg.attn_chunk_unroll)
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    e = cfg.elitekv
    r2 = 2 * e.elite_r
    cache = {"k_e": jnp.zeros((batch, max_len, cfg.n_kv_heads, r2), dtype)}
    if e.lrd == "joint":
        cache["c"] = jnp.zeros((batch, max_len, e.d_ckv), dtype)
    else:
        cache["c_k"] = jnp.zeros((batch, max_len, e.d_ck), dtype)
        cache["c_v"] = jnp.zeros((batch, max_len, e.d_cv), dtype)
    return cache


def _cache_latents(cache):
    if "c" in cache:
        return cache["c"], cache["c"]
    return cache["c_k"], cache["c_v"]


def apply_prefill(params, cfg, buffers, x, positions, cache, constrain=lambda n, t: t):
    from repro.models.attention import _attend
    q, k, v, k_e, c_k, c_v = _materialized(params, cfg, buffers, x, positions, constrain)
    upd = lambda buf, val: jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0,) * buf.ndim)
    new_cache = dict(cache)
    new_cache["k_e"] = upd(cache["k_e"], k_e)
    if "c" in cache:
        new_cache["c"] = upd(cache["c"], c_k)
    else:
        new_cache["c_k"] = upd(cache["c_k"], c_k)
        new_cache["c_v"] = upd(cache["c_v"], c_v)
    o = _attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5,
                chunk_q=cfg.attn_chunk_q, constrain=constrain,
                unroll=cfg.attn_chunk_unroll)
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x.dtype)), new_cache


# ---------------------------------------------------------------------------
# absorbed decode — reads ONLY the compressed cache
# ---------------------------------------------------------------------------

def apply_decode(params, cfg, buffers, x, index, cache, use_kernel: bool = False,
                 constrain=lambda n, t: t):
    """x: [B,1,d].  Returns (out [B,1,d], new_cache)."""
    dt = x.dtype
    e = cfg.elitekv
    B = x.shape[0]
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = cfg.q_group
    pos = jnp.full((B, 1), index, jnp.int32)

    q_e, q_ne = _project_q(params, cfg, x, pos)
    q_e = constrain("attn_q", _rot_q(cfg, buffers, q_e, pos))  # [B,1,nh,2r]
    # absorb bk into the query (activation-level): q_lat [B,1,nh,d_c]
    bk_q = rope_lib.expand_kv_to_q(
        jnp.moveaxis(params["bk"], 1, 0), G)                 # [nh, d_c, d_nope]
    q_lat = constrain("attn_q", jnp.einsum("bshn,hcn->bshc", q_ne, bk_q.astype(dt)))

    # new cache entries
    k_e_new = jnp.einsum("bsd,dhe->bshe", x, params["wk_e"].astype(dt))
    k_e_new = rope_lib.apply_elite_rope(k_e_new, pos, buffers["elite_freqs"])
    c_k_new, c_v_new = _latents(params, cfg, x)
    new_cache = dict(cache)
    new_cache["k_e"] = jax.lax.dynamic_update_slice(
        cache["k_e"], k_e_new.astype(cache["k_e"].dtype), (0, index, 0, 0))
    if "c" in cache:
        new_cache["c"] = jax.lax.dynamic_update_slice(
            cache["c"], c_k_new.astype(cache["c"].dtype), (0, index, 0))
    else:
        new_cache["c_k"] = jax.lax.dynamic_update_slice(
            cache["c_k"], c_k_new.astype(cache["c_k"].dtype), (0, index, 0))
        new_cache["c_v"] = jax.lax.dynamic_update_slice(
            cache["c_v"], c_v_new.astype(cache["c_v"].dtype), (0, index, 0))

    K_e = new_cache["k_e"].astype(dt)                        # [B,S,nkv,2r]
    C_k, C_v = _cache_latents(new_cache)
    C_k, C_v = C_k.astype(dt), C_v.astype(dt)
    Smax = K_e.shape[1]

    if use_kernel:
        from repro.kernels import ops as kops
        lengths = jnp.full((B,), index + 1, jnp.int32)
        o = kops.elite_decode(
            q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k, C_v,
            lengths, q_group=G, scale=dh ** -0.5)
        o = o.reshape(B, 1, nh, C_v.shape[-1])
    else:
        # scores: rotary-elite part (K_e repeated to q heads — GSPMD-clean)
        # + latent part (shared C, no repeat)
        K_e_rep = constrain("heads4", jnp.repeat(K_e, G, axis=2)) if G > 1 else K_e
        s_e = jnp.einsum("bqhe,bkhe->bhqk", q_e, K_e_rep,
                         preferred_element_type=jnp.float32)
        s_lat = jnp.einsum("bqhc,bkc->bhqk", q_lat, C_k,
                           preferred_element_type=jnp.float32)
        s = s_e + s_lat
        s = s * (dh ** -0.5)
        valid = jnp.arange(Smax)[None, None, None, :] <= index
        s = jnp.where(valid, s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(dt)            # [B,nh,1,S]
        o = jnp.einsum("bhqk,bkc->bqhc", p, C_v)             # [B,1,nh,d_c]

    # absorb bv into the output (activation-level)
    bv_q = rope_lib.expand_kv_to_q(jnp.moveaxis(params["bv"], 1, 0), G)  # [nh,d_c,dh]
    o_heads = jnp.einsum("bqhc,hcd->bqhd", o, bv_q.astype(dt))
    out = jnp.einsum("bshe,hed->bsd", o_heads, params["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# paged variants — the cache lives in a shared block pool (serving runtime)
# ---------------------------------------------------------------------------

def _scatter_pages(pages, k_e_new, c_k_new, c_v_new, slot_mapping):
    """Write per-token compressed streams into pool pages at flat slots.
    Out-of-range slots (the inactive-lane / prompt-padding sentinel) are
    dropped.  k_e_new [N,nkv,2r], c_*_new [N,dc], slot_mapping [N].

    Quantized pool (``"k_e_scale" in pages``, see ``core/quant.py``): each
    token row is symmetric-absmax quantized to int8 *here, at write time* —
    a pure function of the row, so chunked/one-shot/preempted/speculative
    write orders all land bit-identical pages — and the per-slot f32 scale is
    scattered beside it through the same drop sentinel."""
    new = dict(pages)
    quantized = "k_e_scale" in pages

    def put(name, val):
        buf = pages[name]
        if quantized:
            q, s = quant.quantize_rows(val)
            new[name] = buf.at[slot_mapping].set(q, mode="drop")
            new[name + "_scale"] = pages[name + "_scale"].at[
                slot_mapping].set(s, mode="drop")
        else:
            new[name] = buf.at[slot_mapping].set(
                val.astype(buf.dtype), mode="drop")

    put("k_e", k_e_new)
    if "c" in pages:
        put("c", c_k_new)
    else:
        put("c_k", c_k_new)
        put("c_v", c_v_new)
    lat_key = "c" if "c" in pages else "c_k"
    if lat_key + "_blkmean" in pages:
        _update_block_summaries(new, lat_key, slot_mapping)
    return new


def _update_block_summaries(pages, key, slot_mapping):
    """Refresh the per-block latent summary rows touched by a scatter.

    ``pages[key + "_blkmean"]/[key + "_blkmax"]`` are [n_blocks, d_c] f32
    (core/cache.py block-summary leaves).  For every written slot's block,
    recompute the masked mean / absmax over that block's VALID rows from the
    just-updated pool content (dequantized for an int8 pool, so summaries are
    always f32 statistics of what attention will actually read).  Valid-row
    count = max written offset + 1 — writes within a block are sequential, so
    the newest offset in this call is the block's live height; a truncated
    block (speculative rejection / preemption) is re-summarized by its next
    write before any read.  Duplicate blocks in one call first scatter-max
    their offsets, then every duplicate writes the identical summary —
    order-independent.  Mutates ``pages`` in place (callers own the dict).
    """
    mean_buf = pages[key + "_blkmean"]
    n_blocks, d_c = mean_buf.shape
    content = pages[key]                                     # post-write
    n_slots = content.shape[0]
    bs = n_slots // n_blocks
    blk = slot_mapping // bs                                 # [N] (oob → drop)
    off = slot_mapping % bs
    maxoff = jnp.zeros((n_blocks,), jnp.int32).at[blk].max(
        off + 1, mode="drop")
    counts = maxoff[blk]                                     # per-entry, agree
    rows_idx = (blk * bs)[:, None] + jnp.arange(bs)[None, :]  # [N, bs]
    rows_idx = jnp.clip(rows_idx, 0, n_slots - 1)
    rows = content[rows_idx].astype(jnp.float32)             # [N, bs, d_c]
    if key + "_scale" in pages:
        rows = rows * pages[key + "_scale"][rows_idx][..., None]
    mask = (jnp.arange(bs)[None, :] < counts[:, None])[..., None]
    cnt = jnp.maximum(counts, 1).astype(jnp.float32)[:, None]
    mean = jnp.where(mask, rows, 0.0).sum(axis=1) / cnt
    amax = jnp.max(jnp.where(mask, jnp.abs(rows), 0.0), axis=1)
    pages[key + "_blkmean"] = mean_buf.at[blk].set(mean, mode="drop")
    pages[key + "_blkmax"] = pages[key + "_blkmax"].at[blk].set(
        amax, mode="drop")


def _page_latents(pages):
    if "c" in pages:
        return pages["c"], pages["c"]
    return pages["c_k"], pages["c_v"]


def _page_scales(pages):
    """Per-slot quantization scales ``(k_e, c_k, c_v)`` — None for an
    unquantized (f32) pool.  J-LRD shares one latent scale for both roles."""
    if "k_e_scale" not in pages:
        return None
    if "c" in pages:
        return pages["k_e_scale"], pages["c_scale"], pages["c_scale"]
    return pages["k_e_scale"], pages["c_k_scale"], pages["c_v_scale"]


def _tp(mesh, tp_axis: str) -> int:
    """Tensor-parallel width of ``mesh`` (1 when unsharded / axis absent)."""
    if mesh is None or tp_axis not in mesh.shape:
        return 1
    return mesh.shape[tp_axis]


def _pin(mesh, x, *spec):
    """Constrain ``x`` to ``PartitionSpec(*spec)`` on ``mesh``.

    Used to force gathered pool reads back to *replicated* before any
    cross-head reduction: the ``k_e`` pages are head-sharded, and without the
    pin GSPMD propagates that sharding into the ``wo`` contraction, summing
    shard partials in a different float order than single-device — which
    breaks the bit-identity serving wall."""
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec)))


def _gather_prefix(pages, params, cfg, block_tables, block_size: int, dt):
    """Materialize K/V for a sequence's cached *prefix* from pool pages.

    block_tables [B, mb] → K_pre [B, mb·bs, nkv, dh], V_pre [B, mb·bs, nkv, dh].
    Positions past the live prefix length land on pool blocks owned by other
    sequences (or the pad block 0) — the caller masks them by ``prefix_lens``.
    The gather reads only the compressed 2r·n_kv + d_ckv floats/token and
    up-projects through bk/bv, mirroring ``kernels.ops.elite_decode_paged``'s
    XLA fallback.
    """
    B, mb = block_tables.shape

    def gather(stream):
        paged = stream.reshape((-1, block_size) + stream.shape[1:])
        return paged[block_tables].reshape((B, mb * block_size) + stream.shape[1:])

    k_e_pre = gather(pages["k_e"]).astype(dt)                # [B,P,nkv,2r]
    c_k_pre, c_v_pre = _page_latents(pages)
    c_k_pre, c_v_pre = gather(c_k_pre).astype(dt), gather(c_v_pre).astype(dt)
    scales = _page_scales(pages)
    if scales is not None:
        # int8 pool: dequantize the gathered prefix rows before up-projecting
        # (one multiply by the per-slot scale — core/quant.py)
        ks, cks, cvs = (gather(s).astype(dt) for s in scales)    # [B,P] each
        k_e_pre = k_e_pre * ks[..., None, None]
        c_k_pre = c_k_pre * cks[..., None]
        c_v_pre = c_v_pre * cvs[..., None]
    k_ne_pre = jnp.einsum("bsc,che->bshe", c_k_pre, params["bk"].astype(dt))
    v_pre = jnp.einsum("bsc,che->bshe", c_v_pre, params["bv"].astype(dt))
    return jnp.concatenate([k_e_pre, k_ne_pre], axis=-1), v_pre


def _attend_resumed(q, k_pre, v_pre, k_cur, v_cur, prefix_lens, q_group: int,
                    scale: float, constrain=lambda n, t: t):
    """Attention for a batch of resumed prefill chunks: lane ``b``'s queries
    see that lane's cached prefix (key j valid iff j < prefix_lens[b] — the
    gather window is padded with foreign blocks) plus its current chunk
    causally.  Everything is per lane, so chunks of *different* sequences at
    different offsets pack into one call; ``prefix_lens[b] == 0`` reduces
    lane ``b`` to ordinary causal prefill (fresh chunk), and all-pad lanes
    produce garbage rows that the caller never reads (their pool writes hit
    the drop sentinel).  q/k_cur/v_cur [B,S,*,dh], k_pre/v_pre [B,P,nkv,dh],
    prefix_lens [B] int32.  → [B,S,nh,dh]."""
    B, S = q.shape[:2]
    P = k_pre.shape[1]
    k = jnp.concatenate([k_pre, k_cur], axis=1)
    v = jnp.concatenate([v_pre, v_cur], axis=1)
    if q_group > 1:
        k = constrain("heads4", jnp.repeat(k, q_group, axis=2))
        v = constrain("heads4", jnp.repeat(v, q_group, axis=2))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    pre_ok = jnp.arange(P)[None, :] < prefix_lens[:, None]   # [B,P]
    cur_ok = jnp.tril(jnp.ones((S, S), bool))                # within-chunk causal
    mask = jnp.concatenate([
        jnp.broadcast_to(pre_ok[:, None, :], (B, S, P)),
        jnp.broadcast_to(cur_ok[None], (B, S, S))], axis=-1) # [B,S,P+S]
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def apply_prefill_paged(params, cfg, buffers, x, positions, pages,
                        slot_mapping, block_tables=None, prefix_lens=None,
                        block_size: int = 0, constrain=lambda n, t: t,
                        mesh=None, tp_axis: str = "model"):
    """Prefill a (chunk of a) sequence and scatter its streams into pool pages.

    Fresh sequences (``block_tables is None``): no prior context, so attention
    is ordinary causal self-attention over the (padded) prompt; only the cache
    *write* is paged.  x [B,S,d]; slot_mapping [B,S] flat pool slots (pad
    positions → sentinel).

    Resumed chunks (chunked prefill): ``positions`` carry the chunks' global
    offsets — [S] when every lane shares one offset, [B,S] when lanes hold
    chunks of different sequences (batched chunked prefill) — and
    ``block_tables`` [B,mb] + ``prefix_lens`` [B] locate each lane's already-
    cached prefix, which is gathered from the pool, up-projected through
    bk/bv, and attended with the per-lane offset causal mask (the XLA
    analogue of ``flash_prefill``'s ``q_offsets``; see docs/serving.md).
    → (out [B,S,d], new_pages)
    """
    from repro.models.attention import _attend
    q, k, v, k_e, c_k, c_v = _materialized(params, cfg, buffers, x, positions,
                                           constrain)
    B, S = x.shape[:2]
    if "k_e_scale" in pages:
        # int8 pool: in-chunk attention must see exactly what a later pool
        # read will dequantize, so round-trip the current chunk's streams
        # before rebuilding K/V — otherwise chunked and one-shot prefill
        # attend over different keys and the golden invariants break
        # (core/quant.py, tests/test_quant.py).  The scatter below still
        # quantizes the RAW streams — the canonical pool content.
        dt = x.dtype
        k_e_rt = quant.roundtrip_rows(k_e, batch_dims=2)
        c_k_rt = quant.roundtrip_rows(c_k, batch_dims=2)
        c_v_rt = quant.roundtrip_rows(c_v, batch_dims=2)
        k_ne = jnp.einsum("bsc,che->bshe", c_k_rt, params["bk"].astype(dt))
        v = constrain("attn_kv", jnp.einsum("bsc,che->bshe", c_v_rt,
                                            params["bv"].astype(dt)))
        k = constrain("attn_kv", jnp.concatenate([k_e_rt, k_ne], axis=-1))
    new_pages = _scatter_pages(
        pages, k_e.reshape(B * S, *k_e.shape[2:]),
        c_k.reshape(B * S, -1), c_v.reshape(B * S, -1),
        slot_mapping.reshape(B * S))
    if block_tables is None:
        o = _attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5,
                    chunk_q=cfg.attn_chunk_q, constrain=constrain,
                    unroll=cfg.attn_chunk_unroll)
    else:
        k_pre, v_pre = _gather_prefix(pages, params, cfg, block_tables,
                                      block_size, x.dtype)
        if _tp(mesh, tp_axis) > 1:
            # Prefill compute is deliberately *replicated* under TP (only the
            # pool page storage is sharded; the slot scatter needs no
            # communication).  The prefix gather is the one place the
            # head-sharded pages leak into activations — pin it back (_pin).
            k_pre = _pin(mesh, k_pre)
            v_pre = _pin(mesh, v_pre)
        o = _attend_resumed(q, k_pre, v_pre, k, v, prefix_lens, cfg.q_group,
                            cfg.head_dim ** -0.5, constrain=constrain)
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x.dtype)), new_pages


def apply_verify_paged(params, cfg, buffers, x, pages, slot_mapping,
                       block_tables, q_offsets, lengths, block_size: int,
                       use_kernel: bool = True, constrain=lambda n, t: t,
                       mesh=None, tp_axis: str = "model"):
    """Absorbed multi-query *verify* attention for speculative decode.

    A verify window is a resumed chunk of ``W = k+1`` tokens — the pending
    token plus ``k`` draft proposals — re-scored by the full model in ONE
    forward: lane ``b``'s window starts at global position ``q_offsets[b]``
    and its rows attend offset-causally to the lane's paged prefix *plus* the
    window itself (whose compressed streams are scattered into the pool
    first, exactly like decode — so accepted tokens' cache entries are
    final full-model values and rejected tokens are erased by truncating the
    pool length, never by rewriting pages).

    Unlike chunked-prefill's ``apply_prefill_paged`` (which gathers the
    prefix and *materializes* K/V through bk/bv), verify stays in the
    absorbed latent space end to end — the same compressed-stream roofline
    as decode, with ``W·n_h`` query rows per lane.

    x [B,W,d]; slot_mapping [B,W] flat pool slots (pad → sentinel);
    q_offsets [B] window start positions; lengths [B] live length including
    the window (0 = dead lane → zero output).  → (out [B,W,d], new_pages).
    """
    dt = x.dtype
    B, W = x.shape[:2]
    dh = cfg.head_dim
    G = cfg.q_group
    pos = q_offsets[:, None] + jnp.arange(W)[None, :]        # [B,W] per-lane

    q_e, q_ne = _project_q(params, cfg, x, pos)
    q_e = constrain("attn_q", _rot_q(cfg, buffers, q_e, pos))
    bk_q = rope_lib.expand_kv_to_q(jnp.moveaxis(params["bk"], 1, 0), G)
    q_lat = constrain("attn_q", jnp.einsum("bshn,hcn->bshc", q_ne, bk_q.astype(dt)))

    k_e_new = jnp.einsum("bsd,dhe->bshe", x, params["wk_e"].astype(dt))
    k_e_new = rope_lib.apply_elite_rope(k_e_new, pos, buffers["elite_freqs"])
    c_k_new, c_v_new = _latents(params, cfg, x)
    new_pages = _scatter_pages(
        pages, k_e_new.reshape(B * W, *k_e_new.shape[2:]),
        c_k_new.reshape(B * W, -1), c_v_new.reshape(B * W, -1),
        slot_mapping.reshape(B * W))

    from repro.kernels import ops as kops
    K_e, (C_k, C_v) = new_pages["k_e"], _page_latents(new_pages)
    scales = _page_scales(new_pages)
    if _tp(mesh, tp_axis) > 1:
        o = kops.elite_verify_paged_tp(
            q_e, q_lat, K_e, C_k, C_v, scales, block_tables, q_offsets,
            lengths, q_group=G, scale=dh ** -0.5, block_size=block_size,
            mesh=mesh, tp_axis=tp_axis, force_xla=not use_kernel)
    elif scales is None:
        o = kops.elite_verify_paged(
            q_e, q_lat, K_e, C_k, C_v, block_tables, q_offsets, lengths,
            q_group=G, scale=dh ** -0.5, block_size=block_size,
            force_xla=not use_kernel)
    else:
        o = kops.elite_verify_paged_q8(
            q_e, q_lat, K_e, C_k, C_v, *scales, block_tables, q_offsets,
            lengths, q_group=G, scale=dh ** -0.5, block_size=block_size,
            force_xla=not use_kernel)
    o = o.astype(dt)                                         # [B,W,nh,d_c]

    bv_q = rope_lib.expand_kv_to_q(jnp.moveaxis(params["bv"], 1, 0), G)
    o_heads = jnp.einsum("bqhc,hcd->bqhd", o, bv_q.astype(dt))
    out = jnp.einsum("bshe,hed->bsd", o_heads, params["wo"].astype(dt))
    return out, new_pages


def apply_decode_paged(params, cfg, buffers, x, pages, slot_mapping,
                       block_tables, lengths, block_size: int,
                       use_kernel: bool = True, constrain=lambda n, t: t,
                       mesh=None, tp_axis: str = "model",
                       sparse_topk: int = 0, sparse_recent: int = 0):
    """Absorbed decode over the block pool — one token per serving slot.

    x [B,1,d]; lengths [B] live length *including* the new token (0 for
    inactive lanes, whose writes hit the sentinel slot and whose attention
    output is zeroed); slot_mapping [B]; block_tables [B,max_blocks].
    → (out [B,1,d], new_pages)

    ``sparse_topk > 0`` switches to latent-space sparse decode: the query is
    scored against the pool's per-block summaries (written by the scatter
    above, so the newest token is always visible) and only the top-k blocks
    plus the ``sparse_recent`` newest are attended — O(k·block) per token.
    Requires a ``block_summaries=True`` pool.  Selection runs on the FULL-head
    query before any tensor-parallel split, so every shard walks identical
    blocks.  ``sparse_topk + sparse_recent >= max_blocks`` selects the whole
    chain and is bit-identical to dense (docs/serving.md, tests/test_sparse.py).
    """
    dt = x.dtype
    B = x.shape[0]
    nh, dh = cfg.n_heads, cfg.head_dim
    G = cfg.q_group
    pos = (lengths - 1)[:, None]                             # [B,1] per-lane

    q_e, q_ne = _project_q(params, cfg, x, pos)
    q_e = constrain("attn_q", _rot_q(cfg, buffers, q_e, pos))
    bk_q = rope_lib.expand_kv_to_q(jnp.moveaxis(params["bk"], 1, 0), G)
    q_lat = constrain("attn_q", jnp.einsum("bshn,hcn->bshc", q_ne, bk_q.astype(dt)))

    k_e_new = jnp.einsum("bsd,dhe->bshe", x, params["wk_e"].astype(dt))
    k_e_new = rope_lib.apply_elite_rope(k_e_new, pos, buffers["elite_freqs"])
    c_k_new, c_v_new = _latents(params, cfg, x)
    new_pages = _scatter_pages(pages, k_e_new[:, 0], c_k_new[:, 0],
                               c_v_new[:, 0], slot_mapping)

    from repro.kernels import ops as kops
    K_e, (C_k, C_v) = new_pages["k_e"], _page_latents(new_pages)
    scales = _page_scales(new_pages)
    if sparse_topk > 0:
        lat_key = "c" if "c" in new_pages else "c_k"
        mb = block_tables.shape[1]
        num_sel = min(sparse_topk + sparse_recent, mb)
        sel_tables, sel_counts = kops.select_topk_blocks(
            q_lat.reshape(B, nh, -1).astype(jnp.float32),
            new_pages[lat_key + "_blkmean"], new_pages[lat_key + "_blkmax"],
            block_tables, lengths, block_size, num_sel, sparse_recent)
        if _tp(mesh, tp_axis) > 1:
            o = kops.elite_decode_sparse_paged_tp(
                q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k,
                C_v, scales, sel_tables, sel_counts, q_group=G,
                scale=dh ** -0.5, block_size=block_size, mesh=mesh,
                tp_axis=tp_axis, force_xla=not use_kernel)
        elif scales is None:
            o = kops.elite_decode_sparse_paged(
                q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k,
                C_v, sel_tables, sel_counts, q_group=G, scale=dh ** -0.5,
                block_size=block_size, force_xla=not use_kernel)
        else:
            o = kops.elite_decode_sparse_paged_q8(
                q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k,
                C_v, *scales, sel_tables, sel_counts, q_group=G,
                scale=dh ** -0.5, block_size=block_size,
                force_xla=not use_kernel)
    elif _tp(mesh, tp_axis) > 1:
        o = kops.elite_decode_paged_tp(
            q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k, C_v,
            scales, block_tables, lengths, q_group=G, scale=dh ** -0.5,
            block_size=block_size, mesh=mesh, tp_axis=tp_axis,
            force_xla=not use_kernel)
    elif scales is None:
        o = kops.elite_decode_paged(
            q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k, C_v,
            block_tables, lengths, q_group=G, scale=dh ** -0.5,
            block_size=block_size, force_xla=not use_kernel)
    else:
        o = kops.elite_decode_paged_q8(
            q_e.reshape(B, nh, -1), q_lat.reshape(B, nh, -1), K_e, C_k, C_v,
            *scales, block_tables, lengths, q_group=G, scale=dh ** -0.5,
            block_size=block_size, force_xla=not use_kernel)
    o = o.reshape(B, 1, nh, C_v.shape[-1]).astype(dt)

    bv_q = rope_lib.expand_kv_to_q(jnp.moveaxis(params["bv"], 1, 0), G)
    o_heads = jnp.einsum("bqhc,hcd->bqhd", o, bv_q.astype(dt))
    out = jnp.einsum("bshe,hed->bsd", o_heads, params["wo"].astype(dt))
    return out, new_pages
