"""Low-rank decomposition of KV projections (paper §3.2).

J-LRD (the paper's choice): jointly factorize
    W^kv = [W^k_nonelite(all heads), W^v(all heads)]  ≈  A^kv · B^kv,
    B^kv = [B^k_J, B^v_J]
so K-up and V-up share one latent — cache/token/layer = 2·r·n_kv + d_ckv.

S-LRD (ablation): factorize W^k_nonelite and W^v separately with ranks
(d_ck, d_cv) — cache = 2·r·n_kv + d_ck + d_cv.  ``optimal_slrd_split`` picks
the error-minimizing (d_ck, d_cv) under a fixed cache budget from the two
singular spectra (the paper used a greedy search; with the spectra in hand the
split is solved exactly).

Stage 2 of docs/architecture.md: the factors produced here become the
``a_kv`` / ``bk`` / ``bv`` weights whose latent stream the paged cache stores
and the decode kernel (kernels/elite_decode.py) reads.

``truncate_joint_rank`` additionally derives the *draft* factors for
self-speculative decode (docs/serving.md): the top singular directions of the
joint ``[bk | bv]`` factor, projected in place — no new trained weights, and
the draft reads the same cached latent stream the full model writes.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np


def svd_lowrank(W: jnp.ndarray, rank: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """W [m,n] ≈ A [m,rank] @ B [rank,n]   (A = U, B = Σ Vᵀ as in paper §2.3)."""
    U, s, Vt = np.linalg.svd(np.asarray(W, np.float64), full_matrices=False)
    A = U[:, :rank]
    B = (s[:rank, None] * Vt[:rank, :])
    return jnp.asarray(A, jnp.float32), jnp.asarray(B, jnp.float32)


def jlrd(wk_ne: jnp.ndarray, wv: jnp.ndarray, d_ckv: int):
    """Joint factorization.

    wk_ne [d, n_kv, d_nope]; wv [d, n_kv, d_h]
    → a_kv [d, d_ckv], bk [d_ckv, n_kv, d_nope], bv [d_ckv, n_kv, d_h]
    """
    d = wk_ne.shape[0]
    nkv, d_nope = wk_ne.shape[1], wk_ne.shape[2]
    dh = wv.shape[2]
    Wk = np.asarray(wk_ne).reshape(d, nkv * d_nope)
    Wv = np.asarray(wv).reshape(d, nkv * dh)
    W = np.concatenate([Wk, Wv], axis=1)
    A, B = svd_lowrank(W, d_ckv)
    bk = B[:, : nkv * d_nope].reshape(d_ckv, nkv, d_nope)
    bv = B[:, nkv * d_nope:].reshape(d_ckv, nkv, dh)
    return A, bk, bv


def slrd(wk_ne: jnp.ndarray, wv: jnp.ndarray, d_ck: int, d_cv: int):
    """Separate factorizations → (a_k, a_v, bk, bv)."""
    d, nkv, d_nope = wk_ne.shape
    dh = wv.shape[2]
    a_k, Bk = svd_lowrank(np.asarray(wk_ne).reshape(d, nkv * d_nope), d_ck)
    a_v, Bv = svd_lowrank(np.asarray(wv).reshape(d, nkv * dh), d_cv)
    return a_k, a_v, Bk.reshape(d_ck, nkv, d_nope), Bv.reshape(d_cv, nkv, dh)


def truncate_joint_rank(bk: jnp.ndarray, bv: jnp.ndarray, rank: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank-truncate the joint up-projection for the *draft* model of
    self-speculative decode (docs/serving.md).

    bk [d_ckv, n_kv, d_nope]; bv [d_ckv, n_kv, d_h].  Stacks them into the
    joint factor B^kv = [bk | bv]  [d_ckv, m], takes the top-``rank`` left
    singular directions P [d_ckv, rank], and projects both factors onto that
    subspace:  bk' = P Pᵀ bk,  bv' = P Pᵀ bv.  Because ``a_kv`` from
    ``jlrd`` is orthonormal (A = U), these are exactly the top singular
    directions of the composed W^kv ≈ a_kv·[bk|bv]; for uptrained factors
    they remain the dominant directions of the latent→KV map.

    The truncated factors keep their full shapes — only their *rank* drops —
    so the draft decoder reads the same d_ckv-wide cached latent stream the
    full model writes (shared pool, no second cache) while its attention
    scores/outputs live in the rank-``rank`` subspace.  ``rank >= d_ckv``
    returns the factors unchanged (the full-rank draft).
    """
    d_ckv = bk.shape[0]
    if rank >= d_ckv:
        return bk, bv
    Bk = np.asarray(bk, np.float64).reshape(d_ckv, -1)
    Bv = np.asarray(bv, np.float64).reshape(d_ckv, -1)
    U, _, _ = np.linalg.svd(np.concatenate([Bk, Bv], axis=1),
                            full_matrices=False)
    proj = U[:, :rank] @ U[:, :rank].T                       # [d_ckv, d_ckv]
    bk_r = (proj @ Bk).reshape(bk.shape)
    bv_r = (proj @ Bv).reshape(bv.shape)
    return (jnp.asarray(bk_r, jnp.float32).astype(bk.dtype),
            jnp.asarray(bv_r, jnp.float32).astype(bv.dtype))


def reconstruction_error(W: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray) -> float:
    W = np.asarray(W, np.float64)
    R = W - np.asarray(A, np.float64) @ np.asarray(B, np.float64)
    return float(np.linalg.norm(R) / max(np.linalg.norm(W), 1e-12))


def optimal_slrd_split(wk_ne: jnp.ndarray, wv: jnp.ndarray, budget: int,
                       align: int = 1) -> Tuple[int, int]:
    """Best (d_ck, d_cv) with d_ck + d_cv = budget, minimizing total squared
    reconstruction error  Σ_{i>d_ck} σ_k,i² + Σ_{i>d_cv} σ_v,i² ."""
    d, nkv, d_nope = wk_ne.shape
    dh = wv.shape[2]
    sk = np.linalg.svd(np.asarray(wk_ne).reshape(d, -1), compute_uv=False)
    sv = np.linalg.svd(np.asarray(wv).reshape(d, -1), compute_uv=False)
    tail = lambda s, r: float(np.sum(s[r:] ** 2))
    best, best_err = None, np.inf
    for ck in range(align, budget, align):
        cv = budget - ck
        if cv < 1 or ck > len(sk) or cv > len(sv):
            continue
        err = tail(sk, ck) + tail(sv, cv)
        if err < best_err:
            best, best_err = (ck, cv), err
    assert best is not None
    return best
