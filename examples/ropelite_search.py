"""Visualize RoPElite frequency preferences (paper Fig. 2) as ASCII heat rows:
which frequency chunks each head of each layer keeps at r=8, under the three
selection methods.

    PYTHONPATH=src python examples/ropelite_search.py
"""
import jax
import numpy as np

from repro.configs import get_config, make_inputs
from repro.core import ropelite
from repro.models import lm


def main():
    cfg = get_config("llama2_7b").reduced(
        num_layers=3, n_heads=8, n_kv_heads=8, d_head=32, d_model=256)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    batch = make_inputs(cfg, 2, 48, "train", seed=7)

    C = cfg.head_dim // 2
    for method in ("greedy", "contribution", "uniform"):
        sets = ropelite.search_model(params, buffers, cfg, batch, r=8,
                                     method=method)
        print(f"\n=== {method} (chunk 0 = highest frequency, {C - 1} = lowest) ===")
        for li in sorted(sets):
            idx = np.asarray(sets[li])
            for h in range(idx.shape[0]):
                row = ["·"] * C
                for rank, c in enumerate(idx[h]):
                    row[int(c)] = str(min(rank + 1, 9))
                print(f"L{li}H{h:<2d} {''.join(row)}")
    print("\ndigits = greedy pick order (1 = most important chunk)")


if __name__ == "__main__":
    main()
