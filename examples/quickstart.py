"""Quickstart: build a small RoPE LM, convert it to EliteKV at a 25% KV cache,
and verify the compressed model decodes correctly.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_inputs
from repro.configs.base import EliteKVConfig
from repro.core import convert
from repro.core.cache import cache_ratio, model_cache_floats_per_token
from repro.models import lm


def main():
    # 1. a small llama-family model (TinyLlama config family, reduced for CPU)
    cfg = get_config("tinyllama_1_1b").reduced(num_layers=4)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    print(f"baseline: {cfg.name}  cache/token = "
          f"{model_cache_floats_per_token(cfg)} floats")

    # 2. RoPElite search + joint low-rank decomposition (paper §3) at ~25%
    calib = make_inputs(cfg, 2, 64, "train", seed=1)
    ek = EliteKVConfig(enabled=True, elite_r=4,
                       d_ckv=int(0.25 * 2 * cfg.n_kv_heads * cfg.head_dim)
                       - 2 * 4 * cfg.n_kv_heads)
    eparams, ebuffers, ecfg = convert.elitekv_from_baseline(
        params, buffers, cfg, calib, ek, method="greedy")
    print(f"elitekv:  r={ek.elite_r} d_ckv={ek.d_ckv}  cache/token = "
          f"{model_cache_floats_per_token(ecfg)} floats  "
          f"(ratio {cache_ratio(ecfg, cfg):.3f})")

    # 3. the compressed model decodes — prefill + absorbed decode against the
    #    compressed cache only
    B, S = 2, 32
    batch = make_inputs(ecfg, B, S, "train", seed=2)
    full_logits, _ = lm.apply_train(eparams, ebuffers, ecfg, batch)
    cache = lm.init_cache(ecfg, B, S, dtype=jnp.float32)
    lp, cache = lm.apply_prefill(eparams, ebuffers, ecfg,
                                 {"tokens": batch["tokens"][:, :S - 4]}, cache)
    err = float(jnp.max(jnp.abs(lp - full_logits[:, :S - 4])))
    for t in range(S - 4, S):
        ld, cache = lm.apply_decode(eparams, ebuffers, ecfg,
                                    {"tokens": batch["tokens"][:, t:t + 1]}, cache)
        err = max(err, float(jnp.max(jnp.abs(ld[:, 0] - full_logits[:, t]))))
    print(f"absorbed-decode max |Δlogit| vs full forward: {err:.2e}  "
          f"(cache never re-rotated)")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
