"""End-to-end driver (paper §4 at miniature scale): pretrain a ~100M-class
RoPE LM on the synthetic corpus for a few hundred steps, convert to EliteKV
at several cache ratios, uptrain each, and report the recovery table.

    PYTHONPATH=src python examples/convert_and_uptrain.py \
        --pretrain-steps 300 --uptrain-steps 150

(Defaults are scaled down so the script finishes on this single CPU core;
crank the flags on real hardware.)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import EliteKVConfig
from repro.core import convert
from repro.core.cache import cache_ratio
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import lm
from repro.runtime import train_loop


def eval_ppl(params, buffers, cfg, seed=123, batches=4):
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    batch_size=4, seed=seed))
    tot = 0.0
    for _ in range(batches):
        loss, _ = lm.loss_fn(params, buffers, cfg, next(data))
        tot += float(loss)
    return float(jnp.exp(tot / batches))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--uptrain-steps", type=int, default=150)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config("tinyllama_1_1b").reduced(
        num_layers=args.layers, d_model=args.dim, n_heads=8, n_kv_heads=4,
        d_head=args.dim // 8, d_ff=args.dim * 3, vocab_size=512)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.2f}M params, vocab {cfg.vocab_size}")

    tc = train_loop.TrainConfig(lr=3e-3)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    batch_size=8, seed=0))
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    t0 = time.time()
    params, _, hist = train_loop.train(
        params, buffers, cfg, tc, iter(data), args.pretrain_steps,
        checkpointer=ck, ckpt_every=100, log_every=50,
        callback=lambda s, m: s % 50 == 0 and print(
            f"  pretrain step {s}: loss {float(m['loss']):.3f}", flush=True))
    base_ppl = eval_ppl(params, buffers, cfg)
    print(f"baseline ppl: {base_ppl:.2f}  ({time.time() - t0:.0f}s)")

    calib = next(TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                          batch_size=2, seed=77)))
    full = 2 * cfg.n_kv_heads * cfg.head_dim
    print(f"\n{'ratio':>6} {'r':>3} {'d_ckv':>6} {'ppl@0':>8} {'ppl@up':>8} "
          f"{'Δvs base':>9}")
    for ratio in (0.5, 0.25, 0.125):
        budget = int(ratio * full)
        r = max(1, min(budget // (4 * cfg.n_kv_heads), cfg.head_dim // 2 - 1))
        d_ckv = budget - 2 * r * cfg.n_kv_heads
        ek = EliteKVConfig(enabled=True, elite_r=r, d_ckv=max(8, d_ckv))
        ep, eb, ecfg = convert.elitekv_from_baseline(
            params, buffers, cfg, {"tokens": calib["tokens"]}, ek)
        ppl0 = eval_ppl(ep, eb, ecfg)
        data_up = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                           batch_size=8, seed=1))
        ep, _, _ = train_loop.train(ep, eb, ecfg, tc, iter(data_up),
                                    args.uptrain_steps, log_every=0)
        ppl1 = eval_ppl(ep, eb, ecfg)
        print(f"{cache_ratio(ecfg, cfg):6.3f} {r:3d} {ek.d_ckv:6d} "
              f"{ppl0:8.2f} {ppl1:8.2f} {ppl1 - base_ppl:+9.2f}")
    print("\n(lower ratio → larger initial hit and slower recovery — paper Fig. 6)")


if __name__ == "__main__":
    main()
