"""Batched serving with the compressed EliteKV cache: a small request mix
(prefill + multi-step greedy decode) with cache accounting per request.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import EliteKVConfig
from repro.core.cache import cache_ratio, measured_cache_bytes
from repro.models import lm
from repro.runtime import serve_loop


def main():
    base = get_config("yi_6b").reduced(num_layers=4)
    elite = dataclasses.replace(
        base, elitekv=EliteKVConfig(enabled=True, elite_r=4, d_ckv=32))
    key = jax.random.PRNGKey(0)

    for tag, cfg in [("baseline-GQA", base), ("EliteKV-25%", elite)]:
        params, buffers = lm.init(key, cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 24), 0,
                                     cfg.vocab_size, jnp.int32)
        t0 = time.time()
        out, stats = serve_loop.generate(params, buffers, cfg, prompts, 16)
        dt = time.time() - t0
        print(f"{tag:14s} ratio={cache_ratio(cfg, base):5.3f}  "
              f"cache={stats.cache_bytes / 2**20:7.2f} MiB  "
              f"{stats.decoded_tokens / dt:6.1f} tok/s  "
              f"sample={out[0, :8].tolist()}")

    print("\nRatio of measured cache bytes should equal the paper formula "
          "(2·r·n_kv + d_ckv) / (2·n_kv·d_h) — see tests/test_serve.py.")


if __name__ == "__main__":
    main()
