"""Preemption-based block management: the golden invariant and the machinery.

Golden tier: with the pool shrunk until residents collide (``OutOfBlocks``
mid-flight), the preempting scheduler must produce *token-identical* output
to the legacy watermark-reservation policy on an ample pool — including
sequences preempted mid-decode whose prefix is recomputed (or host-swapped)
and whose interrupted token is re-drawn from the same logits.  Mechanism
tier: BlockManager admission policies, swap-out/in page fidelity, and
eviction bookkeeping (no leaks, blocks all return).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
from repro.models import lm
from repro.runtime import serve_loop


def _workload(cfg, n_req=4, seed=3, temp=0.0, max_new=10):
    rng = np.random.default_rng(seed)
    return [serve_loop.Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 18))).astype(np.int32),
        max_new_tokens=max_new, arrival=i * 0.5,
        temperature=temp, top_p=0.9, seed=11 + i) for i in range(n_req)]


def _run(params, buffers, cfg, *, num_blocks, admission="preempt",
         eviction="recompute", chunk=4, temp=0.0, max_slots=2):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=4, num_blocks=num_blocks, max_len=48,
        prefill_bucket=4, prefill_chunk_tokens=chunk,
        admission=admission, eviction=eviction)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    report = sched.run(_workload(cfg, temp=temp))
    return {r.uid: list(r.generated) for r in sched.finished}, report, sched


# ---------------------------------------------------------------------------
# golden invariant: preemption never changes tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eviction", ["recompute", "swap"])
def test_preemption_tokens_match_watermark(tiny_elite_cfg, tiny_elite_model,
                                           eviction, stress_blocks):
    """Tiny pool → forced preemptions (including mid-decode, with generated
    tokens recomputed/swapped); output must equal the reservation policy on
    an ample pool, token for token."""
    params, buffers = tiny_elite_model
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             num_blocks=64, admission="watermark")
    assert base_rep.preemptions == 0       # watermark never evicts
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           num_blocks=stress_blocks(9), eviction=eviction)
    assert out == base
    assert rep.completed == base_rep.completed == 4
    assert rep.preemptions > 0             # the tiny pool really forced evictions
    # at least one request was preempted mid-decode (generated tokens already
    # out) and still reproduced its stream exactly
    assert any(p > 0 for r in sched.finished for p in r.preempted_at)
    if eviction == "swap":
        assert rep.swap_outs > 0 and rep.swap_ins == rep.swap_outs
    # every block returned despite the eviction churn
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


@pytest.mark.parametrize("eviction", ["recompute", "swap"])
def test_preemption_sampled_tokens_match(tiny_elite_cfg, tiny_elite_model,
                                         eviction, stress_blocks):
    """Seeded nucleus sampling is preemption-invariant: the re-drawn token
    after a recompute uses the same (seed, token-index) PRNG fold as the
    interrupted decode step would have."""
    params, buffers = tiny_elite_model
    base, _, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=64,
                      admission="watermark", temp=0.8)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg,
                       num_blocks=stress_blocks(9), eviction=eviction,
                       temp=0.8)
    assert rep.preemptions > 0
    assert out == base


def test_oneshot_mode_survives_preemption(tiny_elite_cfg, tiny_elite_model,
                                          stress_blocks):
    """chunk=0 (whole-prompt admission prefill) under a tiny pool: the
    recompute path runs through the one-shot forward too."""
    params, buffers = tiny_elite_model
    base, _, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=64,
                      admission="watermark", chunk=0)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg,
                       num_blocks=stress_blocks(9), chunk=0)
    assert out == base
    assert rep.preemptions > 0


def test_preempt_beats_watermark_occupancy(tiny_elite_cfg, tiny_elite_model):
    """On the same small pool, dropping the reservation raises pool occupancy
    and completes the identical request set — the point of the refactor."""
    params, buffers = tiny_elite_model
    wm, wm_rep, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=12,
                         admission="watermark")
    pr, pr_rep, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=12)
    assert pr == wm
    assert pr_rep.completed == wm_rep.completed == 4
    assert pr_rep.mean_occupancy > wm_rep.mean_occupancy


# ---------------------------------------------------------------------------
# BlockManager mechanism
# ---------------------------------------------------------------------------

def test_block_manager_policies(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    wm = BlockManager(pool, policy="watermark")
    wm.register(0, 6)                      # resident owed 6 blocks, owns 0
    assert wm.reserved_blocks == 6
    assert not wm.can_admit(4, 4)          # 8 free - 6 reserved < 4
    assert wm.can_admit(4, 2)
    wm.grow(0, 9)                          # owns 3 → owed shrinks to 3
    assert wm.reserved_blocks == 3
    wm.release(0)
    assert wm.reserved_blocks == 0 and pool.allocator.num_free == 8

    pr = BlockManager(pool, policy="preempt")
    pr.register(1, 6)
    # preempt admits on the *next allocation*, not the worst case
    assert pr.can_admit(8 * 4, 999) and not pr.can_admit(8 * 4 + 1, 0)


def test_swap_roundtrip_restores_pages(tiny_elite_cfg, tiny_elite_model):
    """Swap-out → swap-in onto a *different* chain reproduces the cached
    streams slot-exactly for the tokens the sequence owns."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    bs, sp = 4, 11
    pool = PagedKVPool(cfg, num_blocks=16, block_size=bs)
    bm = BlockManager(pool)
    pool.ensure_capacity(0, sp)
    tokens = np.zeros((1, 12), np.int32)
    tokens[0, :sp] = np.arange(sp) % cfg.vocab_size
    sm = pool.prefill_slot_mapping(0, 0, sp, 12)[None]
    _, pool.pages = lm.apply_prefill_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(tokens)}, pool.pages,
        jnp.asarray(sm))

    def live(table):
        slots = [b * bs + i for b in table for i in range(bs)][:sp]
        return (np.asarray(pool.pages["p0"]["k_e"])[:, slots].copy(),
                np.asarray(pool.pages["p0"]["c"])[:, slots].copy())

    before = live(pool.block_table(0))
    old_table = pool.block_table(0)
    swapped = bm.preempt_swap_out(0, sp)
    assert swapped.length == sp and pool.block_table(0) == []
    assert bm.preemptions == bm.swap_outs == 1
    # occupy a block so the restored chain cannot be identical
    pool.ensure_capacity(99, 2)
    bm.swap_in(0, swapped)
    assert pool.length(0) == sp
    assert pool.block_table(0) != old_table
    after = live(pool.block_table(0))
    np.testing.assert_allclose(after[0], before[0], atol=0, rtol=0)
    np.testing.assert_allclose(after[1], before[1], atol=0, rtol=0)


def test_swap_in_raises_when_pool_full(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=4, block_size=4)
    bm = BlockManager(pool)
    pool.ensure_capacity(0, 12)            # 3 blocks
    swapped = bm.preempt_swap_out(0, 12)
    pool.ensure_capacity(7, 9)             # steal 3 of 4 blocks
    with pytest.raises(OutOfBlocks):
        bm.swap_in(0, swapped)


def test_preempt_zero_cached_is_plain_requeue(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=4, block_size=4)
    bm = BlockManager(pool)
    assert bm.preempt_swap_out(0, 0) is None
    assert bm.preemptions == 1 and bm.swap_outs == 0
