"""End-to-end behaviour: the paper's full pipeline at miniature scale —
train a baseline RoPE LM → RoPElite search → J-LRD convert → uptrain →
verify recovery + compressed serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EliteKVConfig
from repro.core import convert
from repro.data.pipeline import DataConfig, PipelineState, TokenPipeline
from repro.models import lm
from repro.runtime import serve_loop, train_loop

# the shared pipeline fixture trains three models (~20s setup); every test
# here rides on it, so the whole module is the expensive leg
pytestmark = pytest.mark.slow


def _eval_loss(params, buffers, cfg, n_batches=4):
    """Held-out loss: same seed-0 Markov corpus, pipeline steps the training
    stream never reaches.  Averaged over batches — single-batch train losses
    are too noisy to gate a recovery assertion on."""
    d = iter(TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      batch_size=4, seed=0),
                           state=PipelineState(step=1000)))
    return float(np.mean([float(lm.loss_fn(params, buffers, cfg, next(d))[0])
                          for _ in range(n_batches)]))


@pytest.fixture(scope="module")
def pipeline_result():
    cfg = get_config("tinyllama_1_1b").reduced(
        num_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    batch_size=4, seed=0))
    tc = train_loop.TrainConfig(lr=3e-3)
    params, _, hist = train_loop.train(params, buffers, cfg, tc, iter(data),
                                       60, log_every=5)
    base_loss = hist[-1][1]
    base_eval = _eval_loss(params, buffers, cfg)

    calib = next(iter(TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                               seq_len=32, batch_size=2, seed=9))))
    ek = EliteKVConfig(enabled=True, elite_r=2, d_ckv=8)  # (8+8)/64 = 25%
    ep, eb, ecfg = convert.elitekv_from_baseline(
        params, buffers, cfg, {"tokens": calib["tokens"]}, ek)
    data2 = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     batch_size=4, seed=0))
    conv_loss0 = float(lm.loss_fn(ep, eb, ecfg, next(iter(data2)))[0])
    conv_eval = _eval_loss(ep, eb, ecfg)
    data3 = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                     batch_size=4, seed=0))
    ep, _, hist2 = train_loop.train(ep, eb, ecfg, tc, iter(data3), 160, log_every=5)
    return dict(cfg=cfg, ecfg=ecfg, params=params, buffers=buffers, ep=ep, eb=eb,
                base_loss=base_loss, base_eval=base_eval,
                conv_loss0=conv_loss0, conv_eval=conv_eval,
                uptrained_loss=hist2[-1][1],
                uptrained_eval=_eval_loss(ep, eb, ecfg))


def test_baseline_trains(pipeline_result):
    r = pipeline_result
    assert r["base_loss"] < np.log(128) - 0.1  # below uniform


def test_uptraining_recovers(pipeline_result):
    """Paper Fig. 6 mechanism: conversion hurts, uptraining recovers most.

    Measured on a fixed held-out slice of the training corpus, averaged over
    batches, with a *relative* improvement bound — a raw ``uptrained <
    converted`` on single-batch train losses sat within training noise
    (failed the seed by 0.003) and said nothing about recovery.
    """
    r = pipeline_result
    assert r["conv_loss0"] > r["base_loss"]          # surgery costs something
    # uptraining recovers ≥1% of held-out loss (measured ≈2.6% at 160 steps)
    rel_gain = (r["conv_eval"] - r["uptrained_eval"]) / r["conv_eval"]
    assert rel_gain > 0.01, (r["conv_eval"], r["uptrained_eval"])
    assert r["uptrained_eval"] < r["base_eval"] + 0.25  # lands near baseline


def test_cache_is_quarter(pipeline_result):
    from repro.core.cache import cache_ratio
    r = pipeline_result
    assert cache_ratio(r["ecfg"], r["cfg"]) == pytest.approx(0.25, abs=0.05)


def test_compressed_model_serves(pipeline_result):
    r = pipeline_result
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, 128, jnp.int32)
    out, stats = serve_loop.generate(r["ep"], r["eb"], r["ecfg"], prompts, 4)
    assert out.shape == (2, 4)
    assert stats.cache_bytes > 0
