"""Pallas kernels vs ref.py oracles — shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import elite_decode as ed
from repro.kernels import flash_prefill as fp
from repro.kernels import rope_elite as re_k


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("nkv,G,r2,dc,S,bs", [
    (2, 4, 8, 64, 128, 32),
    (1, 8, 16, 128, 256, 64),
    (4, 1, 4, 32, 64, 64),       # MHA-like, single block
    (2, 2, 8, 96, 96, 32),       # dc not 128-aligned, S==3 blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_elite_decode_sweep(nkv, G, r2, dc, S, bs, dtype):
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 5)
    B = 2
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2), dtype)
    q_lat = jax.random.normal(ks[1], (B, nh, dc), dtype)
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2), dtype)
    c = jax.random.normal(ks[3], (B, S, dc), dtype)
    lengths = jnp.array([S, max(1, S // 3)], jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c, c, lengths, G, 0.1,
                          block_s=bs, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c, c, lengths, G, 0.1)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))


def test_elite_decode_separate_cv():
    """S-LRD: distinct c_k / c_v caches."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    B, nkv, G, r2, dc, S = 1, 2, 2, 4, 32, 64
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, nh, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c_k = jax.random.normal(ks[3], (B, S, dc))
    c_v = jax.random.normal(ks[4], (B, S, dc))
    lengths = jnp.array([40], jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c_k, c_v, lengths, G, 0.2,
                          block_s=16, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c_k, c_v, lengths, G, 0.2)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,nh,nkv,dh,bq,bk", [
    (64, 4, 2, 32, 16, 16),
    (128, 2, 2, 64, 32, 64),
    (96, 8, 2, 16, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(S, nh, nkv, dh, bq, bk, dtype):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, nh, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, dh), dtype)
    o_k = fp.flash_prefill(q, k, v, nh // nkv, dh ** -0.5,
                           block_q=bq, block_k=bk, interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("off,Sq,Sk,bq,bk", [
    (32, 32, 64, 16, 16),        # resume mid-sequence
    (48, 16, 64, 16, 32),        # last chunk, chunk < block_k
    (0, 64, 64, 32, 32),         # offset 0 == ordinary causal
])
def test_flash_prefill_resumed_chunk(off, Sq, Sk, bq, bk):
    """q_offset parity: a resumed chunk must equal the same rows of one-shot
    causal attention over the full sequence."""
    nh, nkv, dh = 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, Sk, nh, dh))
    k = jax.random.normal(ks[1], (B, Sk, nkv, dh))
    v = jax.random.normal(ks[2], (B, Sk, nkv, dh))
    o_full = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5)
    q_chunk = q[:, off:off + Sq]
    o_k = fp.flash_prefill(q_chunk, k, v, nh // nkv, dh ** -0.5,
                           block_q=bq, block_k=bk, q_offset=off,
                           interpret=True)
    o_r = ref.flash_prefill_ref(q_chunk, k, v, nh // nkv, dh ** -0.5,
                                q_offset=off)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_full[:, off:off + Sq]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("offs,lens,bq,bk", [
    ([0, 16, 48], None, 16, 16),           # mixed fresh + resumed lanes
    ([32, 8, 0], [64, 24, 16], 16, 32),    # per-lane padded key tails
    ([48, 48, 48], [64, 64, 0], 16, 16),   # a dead lane (kv_len 0 → zeros)
])
def test_flash_prefill_per_lane_vectors(offs, lens, bq, bk):
    """Per-lane q_offsets/kv_lens: each lane of one packed call must equal a
    separate single-lane call with that lane's scalar offset — the batched
    chunked-prefill contract (chunks of different sequences, one forward)."""
    nh, nkv, dh, Sq, Sk = 4, 2, 32, 16, 64
    B = len(offs)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Sq, nh, dh))
    k = jax.random.normal(ks[1], (B, Sk, nkv, dh))
    v = jax.random.normal(ks[2], (B, Sk, nkv, dh))
    offs_a = jnp.asarray(offs, jnp.int32)
    lens_a = None if lens is None else jnp.asarray(lens, jnp.int32)
    o_k = fp.flash_prefill(q, k, v, nh // nkv, dh ** -0.5, block_q=bq,
                           block_k=bk, q_offset=offs_a, kv_lens=lens_a,
                           interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5,
                                q_offset=offs_a, kv_lens=lens_a)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        if lens is not None and lens[b] < Sq + offs[b]:
            continue                       # scalar int path asserts Sk bounds
        o_b = fp.flash_prefill(q[b:b + 1], k[b:b + 1], v[b:b + 1], nh // nkv,
                               dh ** -0.5, block_q=bq, block_k=bk,
                               q_offset=offs[b], interpret=True)
        if lens is None or lens[b] == Sk:
            np.testing.assert_allclose(np.asarray(o_k[b]), np.asarray(o_b[0]),
                                       atol=2e-5, rtol=2e-5)
    if lens is not None and lens[-1] == 0:
        assert float(jnp.max(jnp.abs(o_k[-1]))) == 0.0


def _verify_inputs(B, W, nkv, G, r2, dc, n_blocks, bs, seed=4):
    """Random paged pool + window queries for the verify kernel tests."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, W, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, W, nh, dc))
    k_e_p = jax.random.normal(ks[2], (n_blocks * bs, nkv, r2))
    c_p = jax.random.normal(ks[3], (n_blocks * bs, dc))
    # disjoint block chains in scrambled physical order
    perm = np.random.default_rng(seed).permutation(n_blocks)
    mb = n_blocks // B
    bt = jnp.asarray(perm[:B * mb].reshape(B, mb), jnp.int32)
    return q_e, q_lat, k_e_p, c_p, bt


@pytest.mark.parametrize("W,offs,lens,bs", [
    (3, [10, 0], [13, 3], 8),      # windows crossing block boundaries
    (5, [6, 30], [11, 35], 8),     # off + W spans 2–3 blocks, uneven lanes
    (2, [0, 0], [2, 0], 4),        # fresh lane + a dead kv_len==0 lane
])
def test_elite_verify_paged_kernel_vs_oracle(W, offs, lens, bs):
    """The k+1-token verify window vs the paged oracle: the Pallas block-
    table walk must reproduce the gather-based reference for windows that
    cross block boundaries, start at position 0 (fresh lane), or are dead
    (kv_len == 0 → exact zeros)."""
    B, nkv, G, r2, dc = 2, 2, 2, 4, 16
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, W, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs)
    offs_a = jnp.asarray(offs, jnp.int32)
    lens_a = jnp.asarray(lens, jnp.int32)
    o_r = ref.elite_verify_paged_ref(q_e, q_lat, k_e_p, c_p, c_p, bt, offs_a,
                                     lens_a, G, 0.2, bs)
    o_k = ed.elite_verify_paged(q_e, q_lat, k_e_p, c_p, c_p, bt, offs_a,
                                lens_a, G, 0.2, bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    for b in range(B):
        if lens[b] == 0:               # dead lane: exact zeros, no uniform-p
            assert float(jnp.max(jnp.abs(o_k[b]))) == 0.0
            assert float(jnp.max(jnp.abs(o_r[b]))) == 0.0


def test_elite_verify_window_matches_flash_mask():
    """Cross-oracle check: the verify window's offset-causal mask is exactly
    ``flash_prefill``'s resumed-chunk diagonal — scoring the same window in
    materialized K/V space (keys = [k_e | c·I], values = c) must agree."""
    B, nkv, G, r2, dc, W = 2, 2, 2, 4, 16, 3
    S = 32
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, W, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, W, nh, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c = jax.random.normal(ks[3], (B, S, dc))
    offs = jnp.asarray([10, 4], jnp.int32)
    lens = jnp.asarray([13, 7], jnp.int32)
    o_v = ref.elite_verify_ref(q_e, q_lat, k_e, c, c, offs, lens, G, 0.2)
    # materialized equivalent: q = [q_e | q_lat] per query head against
    # k = [k_e(kv head) | c] (latent shared across heads); the value carries
    # the latent in its last dc dims (flash keeps one head width throughout)
    q_full = jnp.concatenate([q_e, q_lat], axis=-1)          # [B,W,nh,r2+dc]
    c_h = jnp.broadcast_to(c[:, :, None], (B, S, nkv, dc))
    k_full = jnp.concatenate([k_e, c_h], axis=-1)
    v_full = jnp.concatenate([jnp.zeros((B, S, nkv, r2)), c_h], axis=-1)
    o_f = ref.flash_prefill_ref(q_full, k_full, v_full, G, 0.2,
                                q_offset=offs, kv_lens=lens)
    np.testing.assert_allclose(np.asarray(o_v), np.asarray(o_f[..., r2:]),
                               atol=2e-5, rtol=2e-5)


def test_elite_verify_mixed_decode_lanes():
    """Mixed verify/decode lanes in ONE batched call: a plain decode lane is
    the degenerate window whose row 0 sits at position length-1 (rows past
    the live length produce defined-but-ignored values); its row 0 must
    equal the single-query paged decode oracle while a full verify lane
    rides alongside."""
    B, nkv, G, r2, dc, W, bs = 2, 2, 2, 4, 16, 3, 8
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, W, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs, seed=9)
    dec_len = 14                        # lane 0: plain decode of token 14
    offs = jnp.asarray([dec_len - 1, 5], jnp.int32)    # lane 1: verify window
    lens = jnp.asarray([dec_len, 5 + W], jnp.int32)
    o_v = ed.elite_verify_paged(q_e, q_lat, k_e_p, c_p, c_p, bt, offs, lens,
                                G, 0.2, bs, interpret=True)
    o_r = ref.elite_verify_paged_ref(q_e, q_lat, k_e_p, c_p, c_p, bt, offs,
                                     lens, G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_v), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    # decode lane row 0 == the single-query decode kernel's answer
    o_d = ref.elite_decode_paged_ref(q_e[:, 0], q_lat[:, 0], k_e_p, c_p, c_p,
                                     bt, jnp.asarray([dec_len, 0], jnp.int32),
                                     G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_v[0, 0]), np.asarray(o_d[0]),
                               atol=3e-5, rtol=3e-5)


def test_verify_kernel_matches_model_attention(tiny_elite_cfg, tiny_elite_model):
    """End-to-end: lm.apply_verify_paged's logits row for a 1-token window
    equal lm.apply_decode_paged's for the same state (the W=1 degenerate
    case the scheduler relies on for mixed accounting)."""
    from repro.core.cache import PagedKVPool
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    sp, bsz, mb = 9, 4, 8
    pool = PagedKVPool(cfg, num_blocks=16, block_size=bsz)
    pool.ensure_capacity(0, sp)
    prompt = (np.arange(sp) * 3 % cfg.vocab_size).astype(np.int32)
    toks = np.zeros((1, 12), np.int32)
    toks[0, :sp] = prompt
    sm = pool.prefill_slot_mapping(0, 0, sp, 12)[None]
    _, pool.pages = lm.apply_prefill_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(toks)}, pool.pages,
        jnp.asarray(sm))
    pool.ensure_capacity(0, sp + 1)
    bt = jnp.asarray(pool.block_table_array([0], mb))
    nxt = np.asarray([[17]], np.int32)
    sm1 = jnp.asarray(pool.slot_mapping([0], [sp]))
    dec_logits, _ = lm.apply_decode_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(nxt)}, pool.pages, sm1,
        bt, jnp.asarray([sp + 1], jnp.int32), block_size=bsz)
    ver_logits, _ = lm.apply_verify_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(nxt)}, pool.pages,
        sm1[:, None], bt, jnp.asarray([sp], jnp.int32),
        jnp.asarray([sp + 1], jnp.int32), block_size=bsz)
    np.testing.assert_allclose(np.asarray(ver_logits[0, 0]),
                               np.asarray(dec_logits[0, 0]),
                               atol=2e-4, rtol=2e-4)


def _quantize_pool(k_e_p, c_k_p, c_v_p):
    """Per-slot symmetric absmax int8 pool (core/quant.py layout)."""
    from repro.core import quant
    k_q, k_s = quant.quantize_rows(k_e_p)
    ck_q, ck_s = quant.quantize_rows(c_k_p)
    cv_q, cv_s = quant.quantize_rows(c_v_p)
    return k_q, ck_q, cv_q, k_s, ck_s, cv_s


@pytest.mark.parametrize("lens,bs", [
    ([13, 3], 8),                  # lengths crossing block boundaries
    ([16, 8], 8),                  # lengths exactly on block boundaries
    ([11, 0], 4),                  # live lane + dead kv_len==0 lane
])
def test_elite_decode_paged_q8_kernel_vs_oracle(lens, bs):
    """Fused-dequant paged decode vs the quantized oracle: the in-register
    ``int8 * scale`` multiply must reproduce dequantize-then-attend exactly
    (same matrix as the f32 kernel: boundary kv_lens, dead lanes)."""
    B, nkv, G, r2, dc = 2, 2, 2, 4, 16
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, 1, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs, seed=13)
    q_e, q_lat = q_e[:, 0], q_lat[:, 0]
    k_q, ck_q, cv_q, k_s, ck_s, cv_s = _quantize_pool(k_e_p, c_p, c_p)
    lens_a = jnp.asarray(lens, jnp.int32)
    o_r = ref.elite_decode_paged_q8_ref(q_e, q_lat, k_q, ck_q, cv_q,
                                        k_s, ck_s, cv_s, bt, lens_a,
                                        G, 0.2, bs)
    o_k = ed.elite_decode_paged_q8(q_e, q_lat, k_q, ck_q, cv_q,
                                   k_s, ck_s, cv_s, bt, lens_a,
                                   G, 0.2, bs, interpret=True)
    assert o_k.dtype == jnp.float32        # int8 pages never leak their dtype
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    for b in range(B):
        if lens[b] == 0:
            assert float(jnp.max(jnp.abs(o_k[b]))) == 0.0


@pytest.mark.parametrize("W,offs,lens,bs", [
    (3, [10, 0], [13, 3], 8),      # windows crossing block boundaries
    (5, [6, 30], [11, 35], 8),     # off + W spans 2–3 blocks, uneven lanes
    (2, [0, 0], [2, 0], 4),        # fresh lane + a dead kv_len==0 lane
])
def test_elite_verify_paged_q8_kernel_vs_oracle(W, offs, lens, bs):
    """Quantized verify windows vs the quantized oracle — the exact f32
    verify matrix re-run over an int8 pool with per-slot scales."""
    B, nkv, G, r2, dc = 2, 2, 2, 4, 16
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, W, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs)
    k_q, ck_q, cv_q, k_s, ck_s, cv_s = _quantize_pool(k_e_p, c_p, c_p)
    offs_a = jnp.asarray(offs, jnp.int32)
    lens_a = jnp.asarray(lens, jnp.int32)
    o_r = ref.elite_verify_paged_q8_ref(q_e, q_lat, k_q, ck_q, cv_q,
                                        k_s, ck_s, cv_s, bt, offs_a, lens_a,
                                        G, 0.2, bs)
    o_k = ed.elite_verify_paged_q8(q_e, q_lat, k_q, ck_q, cv_q,
                                   k_s, ck_s, cv_s, bt, offs_a, lens_a,
                                   G, 0.2, bs, interpret=True)
    assert o_k.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    for b in range(B):
        if lens[b] == 0:
            assert float(jnp.max(jnp.abs(o_k[b]))) == 0.0
            assert float(jnp.max(jnp.abs(o_r[b]))) == 0.0


def test_elite_verify_paged_q8_mixed_decode_lanes():
    """Mixed verify/decode lanes over the int8 pool: a W=1-style decode lane
    (row 0 at position length-1) inside a verify call must equal the
    single-query q8 decode oracle — the scheduler's mixed-lane contract
    holds under quantization."""
    B, nkv, G, r2, dc, W, bs = 2, 2, 2, 4, 16, 3, 8
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, W, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs, seed=9)
    k_q, ck_q, cv_q, k_s, ck_s, cv_s = _quantize_pool(k_e_p, c_p, c_p)
    dec_len = 14
    offs = jnp.asarray([dec_len - 1, 5], jnp.int32)
    lens = jnp.asarray([dec_len, 5 + W], jnp.int32)
    o_v = ed.elite_verify_paged_q8(q_e, q_lat, k_q, ck_q, cv_q,
                                   k_s, ck_s, cv_s, bt, offs, lens,
                                   G, 0.2, bs, interpret=True)
    o_r = ref.elite_verify_paged_q8_ref(q_e, q_lat, k_q, ck_q, cv_q,
                                        k_s, ck_s, cv_s, bt, offs, lens,
                                        G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_v), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    o_d = ref.elite_decode_paged_q8_ref(q_e[:, 0], q_lat[:, 0], k_q, ck_q,
                                        cv_q, k_s, ck_s, cv_s, bt,
                                        jnp.asarray([dec_len, 0], jnp.int32),
                                        G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_v[0, 0]), np.asarray(o_d[0]),
                               atol=3e-5, rtol=3e-5)


def test_elite_verify_paged_q8_w1_equals_decode():
    """W=1 quantized verify ≡ quantized decode: the degenerate one-token
    window must be the same computation through both fused-dequant kernels
    (the contract plain decode and speculative verify share)."""
    B, nkv, G, r2, dc, bs = 2, 2, 2, 4, 16, 8
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, 1, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs, seed=5)
    k_q, ck_q, cv_q, k_s, ck_s, cv_s = _quantize_pool(k_e_p, c_p, c_p)
    lens = jnp.asarray([13, 6], jnp.int32)
    o_v = ed.elite_verify_paged_q8(q_e, q_lat, k_q, ck_q, cv_q,
                                   k_s, ck_s, cv_s, bt, lens - 1, lens,
                                   G, 0.2, bs, interpret=True)
    o_d = ed.elite_decode_paged_q8(q_e[:, 0], q_lat[:, 0], k_q, ck_q, cv_q,
                                   k_s, ck_s, cv_s, bt, lens,
                                   G, 0.2, bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_v[:, 0]), np.asarray(o_d),
                               atol=2e-5, rtol=2e-5)


def test_q8_oracle_tracks_f32_oracle():
    """Quality sanity at the kernel level: the quantized oracle's outputs
    stay close to the f32 oracle over the same pool (int8 absmax keeps
    ~2 decimal digits — the serving-level wall is tests/test_quant.py)."""
    B, nkv, G, r2, dc, bs = 2, 2, 2, 4, 16, 8
    q_e, q_lat, k_e_p, c_p, bt = _verify_inputs(B, 1, nkv, G, r2, dc,
                                                n_blocks=16, bs=bs, seed=21)
    q_e, q_lat = q_e[:, 0], q_lat[:, 0]
    k_q, ck_q, cv_q, k_s, ck_s, cv_s = _quantize_pool(k_e_p, c_p, c_p)
    lens = jnp.asarray([13, 9], jnp.int32)
    o_f = ref.elite_decode_paged_ref(q_e, q_lat, k_e_p, c_p, c_p, bt, lens,
                                     G, 0.2, bs)
    o_q = ref.elite_decode_paged_q8_ref(q_e, q_lat, k_q, ck_q, cv_q,
                                        k_s, ck_s, cv_s, bt, lens, G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_f),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("S,H,r,bs", [(64, 4, 4, 16), (32, 2, 8, 32), (128, 1, 2, 64)])
def test_rope_elite_sweep(S, H, r, bs):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, S, H, 2 * r))
    freqs = jnp.exp(-jax.random.uniform(jax.random.PRNGKey(3), (H, r)) * 4)
    pos = jnp.arange(S, dtype=jnp.int32)
    o_k = re_k.rope_elite(x, pos, freqs, block_s=bs, interpret=True)
    o_r = ref.rope_elite_ref(x, pos, freqs)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5, rtol=1e-5)


def test_kernel_matches_model_decode(tiny_elite_cfg, tiny_elite_model):
    """elite_decode kernel output == the model's XLA absorbed-decode internals."""
    from repro.configs import make_inputs
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, S = 2, 16
    batch = make_inputs(cfg, B, S, "train", seed=11)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache = lm.apply_prefill(params, buffers, cfg,
                                {"tokens": batch["tokens"][:, :S - 1]}, cache)
    # layer-0 decode internals
    from repro.core import elite_attention as ea
    from repro.models.layers import rmsnorm
    p0 = jax.tree.map(lambda t: t[0], params["blocks"]["p0"])
    b0 = jax.tree.map(lambda t: t[0], buffers["blocks"]["p0"])
    h = params["embed"]["table"][batch["tokens"][:, S - 1:S]].astype(cfg.dtype)
    hn = rmsnorm(p0["attn_norm"], h, cfg.norm_eps)
    idx = cache["index"]
    c0 = jax.tree.map(lambda t: t[0], cache["blocks"]["p0"])
    out_ref, newc = ea.apply_decode(p0["attn"], cfg, b0, hn, idx, c0)

    # kernel path: rebuild q_e/q_lat exactly as apply_decode does
    from repro.core import rope as rope_lib
    pos = jnp.full((B, 1), idx, jnp.int32)
    q_e, q_ne = ea._project_q(p0["attn"], cfg, hn, pos)
    q_e = ea._rot_q(cfg, b0, q_e, pos)
    G = cfg.q_group
    bk_q = rope_lib.expand_kv_to_q(jnp.moveaxis(p0["attn"]["bk"], 1, 0), G)
    q_lat = jnp.einsum("bshn,hcn->bshc", q_ne, bk_q)
    K_e = newc["k_e"].astype(jnp.float32)
    C = newc["c"].astype(jnp.float32)
    lengths = jnp.full((B,), idx + 1, jnp.int32)
    o_lat = ed.elite_decode(q_e[:, 0], q_lat[:, 0], K_e, C, C, lengths, G,
                            cfg.head_dim ** -0.5, block_s=8, interpret=True)
    bv_q = rope_lib.expand_kv_to_q(jnp.moveaxis(p0["attn"]["bv"], 1, 0), G)
    o_heads = jnp.einsum("bhc,hcd->bhd", o_lat, bv_q)
    out_kernel = jnp.einsum("bhe,hed->bd", o_heads, p0["attn"]["wo"])[:, None]
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_ref),
                               atol=5e-5, rtol=5e-5)
