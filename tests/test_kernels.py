"""Pallas kernels vs ref.py oracles — shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import elite_decode as ed
from repro.kernels import flash_prefill as fp
from repro.kernels import rope_elite as re_k


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("nkv,G,r2,dc,S,bs", [
    (2, 4, 8, 64, 128, 32),
    (1, 8, 16, 128, 256, 64),
    (4, 1, 4, 32, 64, 64),       # MHA-like, single block
    (2, 2, 8, 96, 96, 32),       # dc not 128-aligned, S==3 blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_elite_decode_sweep(nkv, G, r2, dc, S, bs, dtype):
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 5)
    B = 2
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2), dtype)
    q_lat = jax.random.normal(ks[1], (B, nh, dc), dtype)
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2), dtype)
    c = jax.random.normal(ks[3], (B, S, dc), dtype)
    lengths = jnp.array([S, max(1, S // 3)], jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c, c, lengths, G, 0.1,
                          block_s=bs, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c, c, lengths, G, 0.1)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))


def test_elite_decode_separate_cv():
    """S-LRD: distinct c_k / c_v caches."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    B, nkv, G, r2, dc, S = 1, 2, 2, 4, 32, 64
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, nh, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c_k = jax.random.normal(ks[3], (B, S, dc))
    c_v = jax.random.normal(ks[4], (B, S, dc))
    lengths = jnp.array([40], jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c_k, c_v, lengths, G, 0.2,
                          block_s=16, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c_k, c_v, lengths, G, 0.2)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,nh,nkv,dh,bq,bk", [
    (64, 4, 2, 32, 16, 16),
    (128, 2, 2, 64, 32, 64),
    (96, 8, 2, 16, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(S, nh, nkv, dh, bq, bk, dtype):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, nh, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, dh), dtype)
    o_k = fp.flash_prefill(q, k, v, nh // nkv, dh ** -0.5,
                           block_q=bq, block_k=bk, interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("off,Sq,Sk,bq,bk", [
    (32, 32, 64, 16, 16),        # resume mid-sequence
    (48, 16, 64, 16, 32),        # last chunk, chunk < block_k
    (0, 64, 64, 32, 32),         # offset 0 == ordinary causal
])
def test_flash_prefill_resumed_chunk(off, Sq, Sk, bq, bk):
    """q_offset parity: a resumed chunk must equal the same rows of one-shot
    causal attention over the full sequence."""
    nh, nkv, dh = 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, Sk, nh, dh))
    k = jax.random.normal(ks[1], (B, Sk, nkv, dh))
    v = jax.random.normal(ks[2], (B, Sk, nkv, dh))
    o_full = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5)
    q_chunk = q[:, off:off + Sq]
    o_k = fp.flash_prefill(q_chunk, k, v, nh // nkv, dh ** -0.5,
                           block_q=bq, block_k=bk, q_offset=off,
                           interpret=True)
    o_r = ref.flash_prefill_ref(q_chunk, k, v, nh // nkv, dh ** -0.5,
                                q_offset=off)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_full[:, off:off + Sq]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("offs,lens,bq,bk", [
    ([0, 16, 48], None, 16, 16),           # mixed fresh + resumed lanes
    ([32, 8, 0], [64, 24, 16], 16, 32),    # per-lane padded key tails
    ([48, 48, 48], [64, 64, 0], 16, 16),   # a dead lane (kv_len 0 → zeros)
])
def test_flash_prefill_per_lane_vectors(offs, lens, bq, bk):
    """Per-lane q_offsets/kv_lens: each lane of one packed call must equal a
    separate single-lane call with that lane's scalar offset — the batched
    chunked-prefill contract (chunks of different sequences, one forward)."""
    nh, nkv, dh, Sq, Sk = 4, 2, 32, 16, 64
    B = len(offs)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Sq, nh, dh))
    k = jax.random.normal(ks[1], (B, Sk, nkv, dh))
    v = jax.random.normal(ks[2], (B, Sk, nkv, dh))
    offs_a = jnp.asarray(offs, jnp.int32)
    lens_a = None if lens is None else jnp.asarray(lens, jnp.int32)
    o_k = fp.flash_prefill(q, k, v, nh // nkv, dh ** -0.5, block_q=bq,
                           block_k=bk, q_offset=offs_a, kv_lens=lens_a,
                           interpret=True)
    o_r = ref.flash_prefill_ref(q, k, v, nh // nkv, dh ** -0.5,
                                q_offset=offs_a, kv_lens=lens_a)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        if lens is not None and lens[b] < Sq + offs[b]:
            continue                       # scalar int path asserts Sk bounds
        o_b = fp.flash_prefill(q[b:b + 1], k[b:b + 1], v[b:b + 1], nh // nkv,
                               dh ** -0.5, block_q=bq, block_k=bk,
                               q_offset=offs[b], interpret=True)
        if lens is None or lens[b] == Sk:
            np.testing.assert_allclose(np.asarray(o_k[b]), np.asarray(o_b[0]),
                                       atol=2e-5, rtol=2e-5)
    if lens is not None and lens[-1] == 0:
        assert float(jnp.max(jnp.abs(o_k[-1]))) == 0.0


@pytest.mark.parametrize("S,H,r,bs", [(64, 4, 4, 16), (32, 2, 8, 32), (128, 1, 2, 64)])
def test_rope_elite_sweep(S, H, r, bs):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, S, H, 2 * r))
    freqs = jnp.exp(-jax.random.uniform(jax.random.PRNGKey(3), (H, r)) * 4)
    pos = jnp.arange(S, dtype=jnp.int32)
    o_k = re_k.rope_elite(x, pos, freqs, block_s=bs, interpret=True)
    o_r = ref.rope_elite_ref(x, pos, freqs)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5, rtol=1e-5)


def test_kernel_matches_model_decode(tiny_elite_cfg, tiny_elite_model):
    """elite_decode kernel output == the model's XLA absorbed-decode internals."""
    from repro.configs import make_inputs
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, S = 2, 16
    batch = make_inputs(cfg, B, S, "train", seed=11)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache = lm.apply_prefill(params, buffers, cfg,
                                {"tokens": batch["tokens"][:, :S - 1]}, cache)
    # layer-0 decode internals
    from repro.core import elite_attention as ea
    from repro.models.layers import rmsnorm
    p0 = jax.tree.map(lambda t: t[0], params["blocks"]["p0"])
    b0 = jax.tree.map(lambda t: t[0], buffers["blocks"]["p0"])
    h = params["embed"]["table"][batch["tokens"][:, S - 1:S]].astype(cfg.dtype)
    hn = rmsnorm(p0["attn_norm"], h, cfg.norm_eps)
    idx = cache["index"]
    c0 = jax.tree.map(lambda t: t[0], cache["blocks"]["p0"])
    out_ref, newc = ea.apply_decode(p0["attn"], cfg, b0, hn, idx, c0)

    # kernel path: rebuild q_e/q_lat exactly as apply_decode does
    from repro.core import rope as rope_lib
    pos = jnp.full((B, 1), idx, jnp.int32)
    q_e, q_ne = ea._project_q(p0["attn"], cfg, hn, pos)
    q_e = ea._rot_q(cfg, b0, q_e, pos)
    G = cfg.q_group
    bk_q = rope_lib.expand_kv_to_q(jnp.moveaxis(p0["attn"]["bk"], 1, 0), G)
    q_lat = jnp.einsum("bshn,hcn->bshc", q_ne, bk_q)
    K_e = newc["k_e"].astype(jnp.float32)
    C = newc["c"].astype(jnp.float32)
    lengths = jnp.full((B,), idx + 1, jnp.int32)
    o_lat = ed.elite_decode(q_e[:, 0], q_lat[:, 0], K_e, C, C, lengths, G,
                            cfg.head_dim ** -0.5, block_s=8, interpret=True)
    bv_q = rope_lib.expand_kv_to_q(jnp.moveaxis(p0["attn"]["bv"], 1, 0), G)
    o_heads = jnp.einsum("bhc,hcd->bhd", o_lat, bv_q)
    out_kernel = jnp.einsum("bhe,hed->bd", o_heads, p0["attn"]["wo"])[:, None]
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_ref),
                               atol=5e-5, rtol=5e-5)
