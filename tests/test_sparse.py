"""Latent-space sparse decode: block top-k over the paged pool.

Exactness tier: when the selection width covers every resident block
(``topk + recent >= max_blocks_per_seq``) the sparse path must reproduce the
dense paged decode BIT for bit — f32 and int8 pools alike.  The selection
then degenerates to the identity permutation of the block table and the
per-block count mask equals the dense length mask, so the same kernel
arithmetic runs in the same order (docs/serving.md#sparse-decode).

Stability tier: genuinely sparse runs (width < resident blocks) must be
invariant under every pool lifecycle edge — preemption by recompute or host
swap, and prefix-cache block sharing.  Block summaries are a pure function
of block content, so identical streams imply identical selections imply
identical tokens.

Mechanism tier: summary leaves exist exactly when ``block_summaries=True``,
and their values equal a from-scratch masked mean/absmax over the block's
valid rows — recomputed here from the (dequantized) pages themselves, which
is the wall that keeps int8 selection scoring in the f32 world.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PagedKVPool, is_block_summary
from repro.runtime import serve_loop


def _workload(cfg, n_req=4, seed=3, max_new=10, shared=0):
    rng = np.random.default_rng(seed)
    head = (rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
            if shared else None)
    reqs = []
    for i in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(8, 18))).astype(np.int32)
        if head is not None:
            prompt = np.concatenate([head, prompt])
        reqs.append(serve_loop.Request(
            uid=i, prompt=prompt, max_new_tokens=max_new, arrival=i * 0.5))
    return reqs


def _run(params, buffers, cfg, workload, *, topk=0, recent=2, dtype=jnp.float32,
         num_blocks=64, admission="preempt", eviction="recompute", chunk=4,
         max_slots=2, prefix_cache=False, block_size=4, max_len=64):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=block_size, num_blocks=num_blocks,
        max_len=max_len, prefill_bucket=4, prefill_chunk_tokens=chunk,
        admission=admission, eviction=eviction, prefix_cache=prefix_cache,
        cache_dtype=dtype, sparse_topk_blocks=topk,
        sparse_recent_blocks=recent)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    report = sched.run(workload)
    return {r.uid: list(r.generated) for r in sched.finished}, report, sched


# ---------------------------------------------------------------------------
# exactness: full selection width == dense, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, "int8"])
def test_sparse_full_width_bitwise_dense(tiny_elite_cfg, tiny_elite_model,
                                         dtype):
    """``topk + recent >= max_blocks_per_seq`` clamps the selection width to
    the whole block table: top_k over the score row is then a permutation,
    the ascending sort restores the identity, and the per-block count mask
    equals the dense length mask — same arrays, same kernel, same bits.
    Holds for the int8 pool too (selection scores dequantized summaries but
    a full-width selection never drops a block)."""
    params, buffers = tiny_elite_model
    dense, dense_rep, _ = _run(params, buffers, tiny_elite_cfg,
                               _workload(tiny_elite_cfg), dtype=dtype)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg,
                       _workload(tiny_elite_cfg), dtype=dtype, topk=64)
    assert out == dense
    assert dense_rep.sparse_steps == 0 and dense_rep.sparse_topk == 0
    assert rep.sparse_steps > 0 and rep.sparse_topk == 64
    # full width: every resident block attended
    assert rep.mean_selected_blocks == rep.mean_candidate_blocks > 0


def test_sparse_subblock_context(tiny_elite_cfg, tiny_elite_model):
    """Contexts shorter than one block are the degenerate edge: a single
    resident block, forced into the recent tail, count < block_size.  Sparse
    must equal dense exactly and the accounting must report exactly one
    candidate block per lane-step."""
    params, buffers = tiny_elite_model
    prompts = [np.random.default_rng(5 + i).integers(
        0, tiny_elite_cfg.vocab_size, 2 + i).astype(np.int32)
        for i in range(3)]
    wl = lambda: [serve_loop.Request(uid=i, prompt=p, max_new_tokens=4,
                                     arrival=float(i))
                  for i, p in enumerate(prompts)]
    kw = dict(block_size=16, chunk=0, max_len=32)
    dense, _, _ = _run(params, buffers, tiny_elite_cfg, wl(), **kw)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg, wl(), topk=1,
                       recent=1, **kw)
    assert out == dense
    assert rep.sparse_steps > 0
    assert rep.mean_candidate_blocks == 1.0       # never grew past one block
    assert rep.mean_selected_blocks == 1.0


# ---------------------------------------------------------------------------
# stability: genuinely sparse selection across pool lifecycle edges
# ---------------------------------------------------------------------------

def test_sparse_selection_stable_under_swap_preemption(tiny_elite_cfg,
                                                       tiny_elite_model,
                                                       stress_blocks):
    """A genuinely sparse run (width < resident blocks) under forced host
    swap produces the identical streams as an ample undisturbed pool: swap
    carries the chain's pages AND its per-block summary rows byte-exactly,
    so the selection after restore matches the uninterrupted one."""
    params, buffers = tiny_elite_model
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             _workload(tiny_elite_cfg), topk=2, recent=1,
                             num_blocks=64, admission="watermark")
    assert base_rep.preemptions == 0
    # the selection is really partial somewhere in the base run
    assert base_rep.mean_selected_blocks < base_rep.mean_candidate_blocks
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _workload(tiny_elite_cfg), topk=2, recent=1,
                           num_blocks=stress_blocks(9), eviction="swap")
    assert out == base
    assert rep.preemptions > 0
    assert rep.swap_outs > 0 and rep.swap_ins == rep.swap_outs
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


def test_sparse_full_width_stable_under_recompute(tiny_elite_cfg,
                                                  tiny_elite_model,
                                                  stress_blocks):
    """Full selection width is exactly dense, so recompute eviction stays
    sound there: the sparse machinery (summary scatter, selection, sparse
    kernel) runs under preemption pressure and the streams still match the
    undisturbed pool bit for bit."""
    params, buffers = tiny_elite_model
    base, _, _ = _run(params, buffers, tiny_elite_cfg,
                      _workload(tiny_elite_cfg), topk=64, num_blocks=64,
                      admission="watermark")
    out, rep, _ = _run(params, buffers, tiny_elite_cfg,
                       _workload(tiny_elite_cfg), topk=64,
                       num_blocks=stress_blocks(9), eviction="recompute")
    assert out == base
    assert rep.preemptions > 0


def test_sparse_partial_recompute_rejected(tiny_elite_cfg, tiny_elite_model):
    """The one unsound combination — partial selection width with
    recompute-on-preempt — is rejected at construction: dense recompute
    prefill cannot reproduce streams whose lower layers attended sparsely,
    so it would silently fork the output stream."""
    params, buffers = tiny_elite_model
    scfg = serve_loop.SchedulerConfig(
        max_slots=2, block_size=4, num_blocks=16, max_len=64,
        sparse_topk_blocks=2, sparse_recent_blocks=1,
        admission="preempt", eviction="recompute")
    with pytest.raises(AssertionError, match="swap"):
        serve_loop.Scheduler(params, buffers, tiny_elite_cfg, scfg)


def test_sparse_prefix_cache_invariant(tiny_elite_cfg, tiny_elite_model):
    """Prefix-cache hits are invisible to sparse selection: a shared block's
    summary was written by the original prefill from the identical content a
    re-prefill would produce, and COW privatization copies the summary rows
    with the block."""
    params, buffers = tiny_elite_model
    wl = lambda: _workload(tiny_elite_cfg, shared=12, seed=7)
    base, _, _ = _run(params, buffers, tiny_elite_cfg, wl(), topk=2, recent=1,
                      eviction="swap", prefix_cache=False)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg, wl(), topk=2,
                       recent=1, eviction="swap", prefix_cache=True)
    assert out == base
    assert rep.prefix_cache_hits > 0 and rep.prefix_cache_hit_tokens > 0
    assert rep.sparse_steps > 0


# ---------------------------------------------------------------------------
# mechanism: summary leaves and their values (f32 and dequantized-int8)
# ---------------------------------------------------------------------------

def test_sparse_requires_plain_decode(tiny_elite_cfg, tiny_elite_model):
    """Sparse selection scores ONE query per lane; the speculative verify
    window has none, so the combination is rejected at construction."""
    params, buffers = tiny_elite_model
    scfg = serve_loop.SchedulerConfig(
        max_slots=2, block_size=4, num_blocks=16, max_len=32,
        sparse_topk_blocks=2, speculate_k=2)
    with pytest.raises(AssertionError, match="mutually exclusive"):
        serve_loop.Scheduler(params, buffers, tiny_elite_cfg, scfg)


@pytest.mark.parametrize("dtype", [jnp.float32, "int8"])
def test_block_summary_parity_vs_recompute(tiny_elite_cfg, tiny_elite_model,
                                           dtype):
    """Stored summary leaves equal a from-scratch masked mean/absmax over
    each chain block's valid rows, computed here from the pages themselves —
    DEQUANTIZED first for the int8 pool, so selection scoring sees f32-world
    statistics regardless of the storage dtype.  Off-chain blocks stay
    zero."""
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    bs, sp = 4, 11
    pool = PagedKVPool(cfg, num_blocks=16, block_size=bs, dtype=dtype,
                       block_summaries=True)
    latent = "c" if "c" in pool.pages["p0"] else "c_k"
    for layer in pool.pages.values():
        assert layer[latent + "_blkmean"].dtype == jnp.float32
        assert layer[latent + "_blkmax"].shape == \
            (layer[latent].shape[0], pool.num_blocks, layer[latent].shape[-1])
    prompt = (np.arange(sp) * 5 % cfg.vocab_size).astype(np.int32)
    pool.ensure_capacity(0, sp)
    toks = np.zeros((1, 12), np.int32)
    toks[0, :sp] = prompt
    sm = pool.prefill_slot_mapping(0, 0, sp, 12)[None]
    _, pool.pages = lm.apply_prefill_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(toks)}, pool.pages,
        jnp.asarray(sm))
    chain = pool.block_table(0)
    for layer in pool.pages.values():
        content = np.asarray(layer[latent], np.float32)   # [n_super, slots, d]
        if latent + "_scale" in layer:
            content = content * np.asarray(
                layer[latent + "_scale"], np.float32)[..., None]
        mean = np.asarray(layer[latent + "_blkmean"])
        amax = np.asarray(layer[latent + "_blkmax"])
        for j, b in enumerate(chain):
            count = min(sp - j * bs, bs)
            rows = content[:, b * bs:b * bs + count]      # valid rows only
            np.testing.assert_allclose(mean[:, b], rows.mean(axis=1),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(amax[:, b],
                                       np.abs(rows).max(axis=1),
                                       atol=1e-5, rtol=1e-5)
        off_chain = [b for b in range(pool.num_blocks) if b not in chain]
        assert not mean[:, off_chain].any()
        assert not amax[:, off_chain].any()


def test_summary_leaves_gated_by_flag(tiny_elite_cfg):
    """No sparse flag, no summary leaves — the dense pool's page pytree (and
    its bytes/token accounting) is untouched by this feature."""
    dense = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    sparse = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4,
                         block_summaries=True)
    assert not any(is_block_summary(k) for layer in dense.pages.values()
                   for k in layer)
    assert any(is_block_summary(k) for layer in sparse.pages.values()
               for k in layer)
