"""Data pipeline determinism/resume + optimizer behaviour (fp32/bf16/int8)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, PipelineState, TokenPipeline
from repro.optim import adamw, schedule
from repro.optim.adamw import AdamWConfig


def _cfg(**kw):
    d = dict(vocab_size=64, seq_len=8, batch_size=2, seed=3)
    d.update(kw)
    return DataConfig(**d)


def test_pipeline_deterministic():
    a = [np.asarray(next(iter(TokenPipeline(_cfg())))["tokens"]) for _ in range(1)]
    b = [np.asarray(next(iter(TokenPipeline(_cfg())))["tokens"]) for _ in range(1)]
    np.testing.assert_array_equal(a[0], b[0])


def test_pipeline_resume_equivalence():
    p1 = TokenPipeline(_cfg())
    seq1 = [np.asarray(next(p1)["tokens"]) for _ in range(5)]
    # resume from state after 2 steps
    p2 = TokenPipeline(_cfg())
    for _ in range(2):
        next(p2)
    p3 = TokenPipeline(_cfg(), state=PipelineState(**p2.state.to_dict()))
    for got, want in zip([np.asarray(next(p3)["tokens"]) for _ in range(3)], seq1[2:]):
        np.testing.assert_array_equal(got, want)


def test_pipeline_hosts_differ():
    a = np.asarray(next(iter(TokenPipeline(_cfg(host_id=0, num_hosts=2))))["tokens"])
    b = np.asarray(next(iter(TokenPipeline(_cfg(host_id=1, num_hosts=2))))["tokens"])
    assert not np.array_equal(a, b)


def test_labels_are_shifted_tokens():
    batch = next(iter(TokenPipeline(_cfg())))
    np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                  np.asarray(batch["labels"][:, :-1]))


def test_file_mode(tmp_path):
    from repro.data.pipeline import write_token_shards
    toks = np.arange(5000, dtype=np.int32)
    write_token_shards(toks, str(tmp_path), shard_size=2048)
    p = TokenPipeline(_cfg(kind="file", path=str(tmp_path)))
    batch = next(p)
    assert batch["tokens"].shape == (2, 8)
    assert int(batch["tokens"].max()) < 64


# ---------------------------------------------------------------------------

def _rosenbrockish(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


@pytest.mark.parametrize("mdtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(mdtype):
    cfg = AdamWConfig(moment_dtype=mdtype, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros(8)}
    st = adamw.init(params, cfg)
    loss0 = float(_rosenbrockish(params))
    for _ in range(200):
        g = jax.grad(_rosenbrockish)(params)
        params, st, _ = adamw.update(g, st, params, 0.05, cfg)
    assert float(_rosenbrockish(params)) < loss0 * 0.01, mdtype


def test_int8_moment_quant_error_bounded():
    from repro.optim.adamw import _dequant, _quant
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 5
    q = _quant(x)
    err = jnp.abs(_dequant(q) - x)
    scale = q["s"]
    assert float(jnp.max(err / scale)) <= 0.5 + 1e-3  # round-to-nearest bound


def test_grad_clip():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    st = adamw.init(params, cfg)
    g = {"w": jnp.full(3, 100.0)}
    _, _, m = adamw.update(g, st, params, 0.1, cfg)
    assert float(m["grad_norm"]) > 100  # reported pre-clip


def test_wsd_schedule_phases():
    fn = schedule.wsd(1.0, warmup=10, stable=20, decay=10)
    assert float(fn(0)) == 0.0
    assert float(fn(5)) == pytest.approx(0.5)
    assert float(fn(15)) == pytest.approx(1.0)
    assert float(fn(25)) == pytest.approx(1.0)
    assert float(fn(40)) < 0.05


def test_cosine_schedule():
    fn = schedule.cosine(1.0, warmup=10, total=110)
    assert float(fn(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(110)) == pytest.approx(0.1, rel=1e-2)


def test_grad_compression_error_feedback():
    from repro.runtime.train_loop import _compress_grads
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8))}
    e = {"w": jnp.zeros((8, 8))}
    ghat, e1 = _compress_grads(g, e)
    # error feedback: residual equals quantization error
    np.testing.assert_allclose(np.asarray(g["w"] - ghat["w"]), np.asarray(e1["w"]),
                               atol=1e-6)
    # accumulated error shrinks the long-run bias: two rounds with the same g
    ghat2, e2 = _compress_grads(g, e1)
    total = np.asarray(ghat["w"] + ghat2["w"]) / 2
    np.testing.assert_allclose(total, np.asarray(g["w"]),
                               atol=float(jnp.max(jnp.abs(g["w"]))) / 64)
