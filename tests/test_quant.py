"""Int8 latent-pool quantization: the quality wall and the golden invariants.

Quality tier: switching the pool to int8 must keep greedy paged-decode
streams in top-1 agreement with the f32 pool above a pinned threshold, with
a bounded per-position decode-logit MAE, while shrinking bytes/token well
below the acceptance ceiling (docs/serving.md).

Golden tier: every serving invariant the f32 pool ships with must also hold
*within* the quantized world, each leg quantized-vs-quantized so the
quantization error cancels and the streams must be BIT-identical —
chunked == one-shot prefill, preemption (recompute and swap) == undisturbed,
prefix-cache on == off, speculative == plain.  These hold because the int8
representation is a pure function of each token row (per-token scales,
core/quant.py) and in-chunk prefill attention round-trips its own streams.

Mechanism tier: pool/report accounting (dtype, bytes/token, peak bytes) and
scale-leaf existence in every layer's pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PagedKVPool
from repro.runtime import serve_loop

#: pinned quality wall — teacher-forced per-position top-1 agreement of the
#: int8 pool vs the f32 pool on the suite's tiny random-init model.  Forced
#: (not free-running) because a single early argmax flip would otherwise
#: diverge the context and corrupt every later comparison.  The tiny model's
#: intrinsic per-position flip rate is ~1%, so the wall pins 0.95 with
#: margin; the acceptance artifact (benchmarks/run.py ``pool_capacity_int8``)
#: pins the headline >= 0.98 on its fixed benchmark seed.
TOP1_AGREEMENT_MIN = 0.95
#: f32-vs-int8 decode-logit mean absolute error ceiling on the tiny model
LOGIT_MAE_MAX = 0.05
#: bytes/token ceiling: int8 pool vs f32 pool (acceptance: <= 0.55x)
BYTES_RATIO_MAX = 0.55


def _workload(cfg, n_req=4, seed=3, temp=0.0, max_new=10, shared=0):
    rng = np.random.default_rng(seed)
    head = (rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
            if shared else None)
    reqs = []
    for i in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(8, 18))).astype(np.int32)
        if head is not None:
            prompt = np.concatenate([head, prompt])
        reqs.append(serve_loop.Request(
            uid=i, prompt=prompt, max_new_tokens=max_new, arrival=i * 0.5,
            temperature=temp, top_p=0.9, seed=11 + i))
    return reqs


def _run(params, buffers, cfg, workload, *, dtype="int8", num_blocks=64,
         admission="preempt", eviction="recompute", chunk=4, max_slots=2,
         spec_k=0, rank=0, prefix_cache=False, block_size=4):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=block_size, num_blocks=num_blocks,
        max_len=64, prefill_bucket=4, prefill_chunk_tokens=chunk,
        admission=admission, eviction=eviction,
        speculate_k=spec_k, draft_rank=rank, prefix_cache=prefix_cache,
        cache_dtype=dtype)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    report = sched.run(workload)
    return {r.uid: list(r.generated) for r in sched.finished}, report, sched


# ---------------------------------------------------------------------------
# quality wall: int8 vs f32 (the one approximate comparison in this file)
# ---------------------------------------------------------------------------

def test_int8_top1_agreement_and_footprint(tiny_elite_cfg, tiny_elite_model):
    """The headline trade: teacher-forced per-position argmax over the int8
    pool agrees with the f32 pool above the pinned top-1 threshold while
    bytes/token drop below the acceptance ceiling.  Both pools score the
    IDENTICAL f32-greedy streams, so every position is an independent
    comparison (free-running streams would compound one flip forever)."""
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, P, new = 4, 16, 12
    prompts = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)

    def gen(dtype):
        scfg = serve_loop.SchedulerConfig(
            max_slots=B, max_new_tokens=new, max_len=32, num_blocks=48,
            block_size=8, cache_dtype=dtype)
        return serve_loop.generate_paged(params, buffers, cfg, prompts, new,
                                         scfg)

    out_f, rep_f = gen(jnp.float32)
    _, rep_q = gen("int8")
    assert rep_q.pool_dtype == "int8" and rep_f.pool_dtype == "float32"
    ratio = rep_q.pool_bytes_per_token / rep_f.pool_bytes_per_token
    assert ratio <= BYTES_RATIO_MAX, ratio
    assert rep_q.pool_allocated_bytes_peak < rep_f.pool_allocated_bytes_peak

    full = jnp.concatenate([prompts, jnp.asarray(out_f)], axis=1)
    n = int(full.shape[1])

    def forced_logits(dtype):
        pool = PagedKVPool(cfg, num_blocks=4 * B, block_size=8, dtype=dtype)
        sms = []
        for b in range(B):
            pool.ensure_capacity(b, n)
            sms.append(pool.prefill_slot_mapping(b, 0, n, n))
        logits, _ = lm.apply_prefill_paged(
            params, buffers, cfg, {"tokens": full}, pool.pages,
            jnp.asarray(np.stack(sms)))
        return np.asarray(logits, np.float32)[:, P - 1:n - 1]

    l_f = forced_logits(jnp.float32)
    l_q = forced_logits("int8")
    # the metric is sound: f32 teacher-forcing reproduces its own stream
    assert (l_f.argmax(-1) == np.asarray(out_f)).all()
    agreement = float((l_f.argmax(-1) == l_q.argmax(-1)).mean())
    assert agreement >= TOP1_AGREEMENT_MIN, agreement


def test_int8_decode_logit_mae_bounded(tiny_elite_cfg, tiny_elite_model):
    """Per-position decode logits over an int8 pool stay within a small MAE
    of the f32 pool after an identical prefill — the quantization noise the
    top-1 wall rides on is itself bounded."""
    from repro.models import lm
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    bs, sp = 4, 11
    prompt = (np.arange(sp) * 5 % cfg.vocab_size).astype(np.int32)

    def decode_logits(dtype):
        pool = PagedKVPool(cfg, num_blocks=16, block_size=bs, dtype=dtype)
        pool.ensure_capacity(0, sp)
        toks = np.zeros((1, 12), np.int32)
        toks[0, :sp] = prompt
        sm = pool.prefill_slot_mapping(0, 0, sp, 12)[None]
        _, pool.pages = lm.apply_prefill_paged(
            params, buffers, cfg, {"tokens": jnp.asarray(toks)}, pool.pages,
            jnp.asarray(sm))
        pool.ensure_capacity(0, sp + 1)
        bt = jnp.asarray(pool.block_table_array([0], 8))
        sm1 = jnp.asarray(pool.slot_mapping([0], [sp]))
        logits, _ = lm.apply_decode_paged(
            params, buffers, cfg, {"tokens": jnp.asarray([[17]], np.int32)},
            pool.pages, sm1, bt, jnp.asarray([sp + 1], jnp.int32),
            block_size=bs)
        return np.asarray(logits[0, 0], np.float32)

    l_f = decode_logits(jnp.float32)
    l_q = decode_logits("int8")
    mae = float(np.mean(np.abs(l_f - l_q)))
    assert mae <= LOGIT_MAE_MAX, mae
    # the wall is not vacuous: quantization really perturbs the logits
    assert mae > 0.0


# ---------------------------------------------------------------------------
# golden invariants, quantized-vs-quantized (bit-identical streams)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_int8_chunked_equals_oneshot(tiny_elite_cfg, tiny_elite_model, temp):
    """Chunked prefill over the int8 pool equals one-shot prefill token for
    token: in-chunk attention round-trips its own streams, so every read —
    same chunk, later chunk, or decode — sees identical dequantized values
    regardless of chunk boundaries."""
    params, buffers = tiny_elite_model
    one, one_rep, _ = _run(params, buffers, tiny_elite_cfg,
                           _workload(tiny_elite_cfg, temp=temp), chunk=0)
    for chunk in (4, 6):
        out, rep, _ = _run(params, buffers, tiny_elite_cfg,
                           _workload(tiny_elite_cfg, temp=temp), chunk=chunk)
        assert out == one
        assert rep.completed == one_rep.completed == 4
        assert rep.pool_dtype == "int8"


@pytest.mark.parametrize("eviction", ["recompute", "swap"])
def test_int8_preemption_invariant(tiny_elite_cfg, tiny_elite_model, eviction,
                                   stress_blocks):
    """Tiny int8 pool under forced preemption (recompute or host swap)
    produces the identical streams as an ample undisturbed int8 pool —
    requantizing a recomputed prefix is a pure function of the tokens, and
    swap round-trips the int8 pages byte-exactly."""
    params, buffers = tiny_elite_model
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             _workload(tiny_elite_cfg), num_blocks=64,
                             admission="watermark")
    assert base_rep.preemptions == 0
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _workload(tiny_elite_cfg),
                           num_blocks=stress_blocks(9), eviction=eviction)
    assert out == base
    assert rep.preemptions > 0
    if eviction == "swap":
        assert rep.swap_outs > 0 and rep.swap_ins == rep.swap_outs
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


def test_int8_prefix_cache_invariant(tiny_elite_cfg, tiny_elite_model):
    """Prefix-cache hits over the int8 pool are invisible in the stream:
    cached pages are bit-identical to what a re-prefill would have written
    (quantization is content-addressed-friendly — pure per-token)."""
    params, buffers = tiny_elite_model
    wl = lambda: _workload(tiny_elite_cfg, shared=12, seed=7)
    base, _, _ = _run(params, buffers, tiny_elite_cfg, wl(),
                      prefix_cache=False)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg, wl(),
                           prefix_cache=True)
    assert out == base
    assert rep.prefix_cache_hits > 0 and rep.prefix_cache_hit_tokens > 0
    retained = sched.bm.prefix.num_retained if sched.bm.prefix else 0
    assert sched.pool.allocator.num_free + retained == sched.pool.num_blocks


@pytest.mark.parametrize("spec_k", [2, 4])
def test_int8_speculative_matches_plain(tiny_elite_cfg, tiny_elite_model,
                                        spec_k, stress_blocks):
    """Greedy self-speculative decode over the int8 pool is bit-identical to
    plain int8 decode: draft and verify read the same quantized pages, and
    rejected windows roll back by truncation (scales truncate with their
    rows)."""
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             _workload(tiny_elite_cfg), num_blocks=nb)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _workload(tiny_elite_cfg), num_blocks=nb,
                           spec_k=spec_k)
    assert out == base
    assert rep.acceptance_rate == 1.0        # full-rank draft ≡ target
    assert rep.decode_steps < base_rep.decode_steps
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


# ---------------------------------------------------------------------------
# mechanism: pool accounting and scale leaves
# ---------------------------------------------------------------------------

def test_int8_pool_pages_and_stats(tiny_elite_cfg):
    """Every layer's pages carry int8 streams plus f32 per-slot scale leaves,
    and the stats/bytes accounting reflects the quantized layout."""
    pool_f = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    pool_q = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4,
                         dtype="int8")
    assert pool_q.quantized and not pool_f.quantized
    for layer in pool_q.pages.values():
        assert layer["k_e"].dtype == jnp.int8
        assert layer["k_e_scale"].dtype == jnp.float32
        # leading n_super axis + flat slot axis, no per-feature dims
        assert layer["k_e_scale"].shape == layer["k_e"].shape[:2]
        latent = "c" if "c" in layer else "c_k"
        assert layer[latent].dtype == jnp.int8
        assert layer[latent + "_scale"].dtype == jnp.float32
    assert all("_scale" not in k for layer in pool_f.pages.values()
               for k in layer)
    sf, sq = pool_f.stats(), pool_q.stats()
    assert sq.dtype == "int8" and sf.dtype == "float32"
    assert 0 < sq.bytes_per_token < sf.bytes_per_token
    assert sq.bytes_per_token / sf.bytes_per_token <= BYTES_RATIO_MAX
