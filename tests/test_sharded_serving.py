"""Sharded-serving acceptance wall: token identity across (tp, dp).

The conftest pins this process to one CPU device, so every multi-device
configuration runs ``repro.runtime.sharded_check`` in a subprocess that
forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before
importing jax.  Each worker serves the SAME deterministic greedy request set
through five scheduler scenarios (chunked prefill + swap preemption,
recompute preemption, prefix cache, int8 pool, speculative decode) and the
tests assert the per-request token streams are EXACTLY equal to the
single-device run — head-sharded absorbed attention (the heads are batch
dims, the all_gather epilogue restores full-head activations before the
only cross-head reduction) and the data-parallel router (independent
replicas, count-folded per-request PRNG) are both bit-preserving by
construction, so any drift is a real bug, not tolerance noise.

One subprocess per (tp, dp) serves all scenarios; results are memoised
module-wide so parametrised tests don't respawn workers.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCENARIOS = "plain,recompute,prefix,int8,spec"
_cache = {}


def _worker(tp, dp, *, parity=False, devices=8):
    key = (tp, dp, parity)
    if key in _cache:
        return _cache[key]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.runtime.sharded_check",
           "--devices", str(devices), "--tp", str(tp), "--dp", str(dp)]
    cmd += ["--parity"] if parity else ["--scenarios", SCENARIOS]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=560)
    assert proc.returncode == 0, (
        f"sharded_check tp={tp} dp={dp} parity={parity} failed:\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-4000:]}")
    out = json.loads(proc.stdout)
    assert out["devices"] == devices
    _cache[key] = out
    return out


def test_shard_map_epilogue_kernel_parity():
    """Direct kernel check: the shard_map decode/verify epilogue is bitwise
    equal to the single-device paged kernels (f32 and int8 pages)."""
    res = _worker(0, 0, parity=True)["parity"]
    assert res == {k: True for k in res}, res


@pytest.mark.parametrize("scenario", SCENARIOS.split(","))
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_tp_token_identity(tp, scenario):
    ref = _worker(1, 1)["scenarios"][scenario]
    got = _worker(tp, 1)["scenarios"][scenario]
    assert got["tokens"] == ref["tokens"], (
        f"tp={tp} {scenario}: sharded stream diverged from single-device")
    assert got["report"]["completed"] == ref["report"]["completed"]


@pytest.mark.parametrize("scenario", SCENARIOS.split(","))
def test_tp2_dp2_token_identity(scenario):
    """tp=2 x dp=2 (4 of the 8 forced devices): the router's merged streams
    equal the single-scheduler single-device run, scenario by scenario."""
    ref = _worker(1, 1)["scenarios"][scenario]
    got = _worker(2, 2)["scenarios"][scenario]
    assert got["tokens"] == ref["tokens"], (
        f"tp2xdp2 {scenario}: routed streams diverged from single-device")
    rep = got["report"]
    assert sum(rep["routed"]) == len(ref["tokens"])
    assert len(rep["occupancy_per_replica"]) == 2


def test_per_device_pool_bytes_shrink_with_tp():
    """Head-sharding the k_e pages cuts per-device pool bytes/token; the
    replicated latent pages keep it from scaling 1/tp exactly."""
    b1 = _worker(1, 1)["scenarios"]["plain"]["report"][
        "pool_bytes_per_token_per_device"]
    b2 = _worker(2, 1)["scenarios"]["plain"]["report"][
        "pool_bytes_per_token_per_device"]
    b4 = _worker(4, 1)["scenarios"]["plain"]["report"][
        "pool_bytes_per_token_per_device"]
    assert b1 > b2 > b4
    assert b4 >= b1 // 4
