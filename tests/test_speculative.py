"""Self-speculative decode over the compressed latent cache: the golden wall.

Golden tier: draft/verify macro-steps must be *invisible in the token
stream* — greedy speculative output is identical to plain paged decode for
every window size and draft rank (acceptance only changes how many forwards
it takes), including under tiny-pool preemption mid-verify; and with the
full-rank draft, seeded temperature/top-p streams match plain decode exactly
because every proposal is accepted (the draft IS the target).  Mechanism
tier: pool-chain rollback conservation, acceptance accounting, and the
benchmark workload's seeding regression.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PagedKVPool
from repro.models import lm
from repro.runtime import serve_loop


def _workload(cfg, n_req=4, seed=3, temp=0.0, max_new=10):
    rng = np.random.default_rng(seed)
    return [serve_loop.Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 18))).astype(np.int32),
        max_new_tokens=max_new, arrival=i * 0.5,
        temperature=temp, top_p=0.9, seed=11 + i) for i in range(n_req)]


def _run(params, buffers, cfg, *, num_blocks=64, spec_k=0, rank=0, temp=0.0,
         chunk=4, eviction="recompute", max_slots=2, eos_id=None):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=4, num_blocks=num_blocks, max_len=48,
        prefill_bucket=4, prefill_chunk_tokens=chunk, eviction=eviction,
        eos_id=eos_id, speculate_k=spec_k, draft_rank=rank)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    report = sched.run(_workload(cfg, temp=temp))
    return {r.uid: list(r.generated) for r in sched.finished}, report, sched


# ---------------------------------------------------------------------------
# golden invariant: speculative greedy == plain greedy, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [1, 2, 4])
@pytest.mark.parametrize("rank", [0, 16])     # full-rank and truncated drafts
def test_greedy_speculative_matches_plain(tiny_elite_cfg, tiny_elite_model,
                                          spec_k, rank, stress_blocks):
    """Any window size × any draft rank: greedy streams are bit-identical to
    plain paged decode — rejected drafts roll the pool back, accepted ones
    are exactly the argmax the plain path would have emitted."""
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=nb)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg, num_blocks=nb,
                           spec_k=spec_k, rank=rank)
    assert out == base
    assert rep.completed == base_rep.completed == 4
    assert rep.speculate_k == spec_k and rep.draft_rank == rank
    # the macro-step really advanced multiple tokens per verify forward for
    # the full-rank draft (acceptance 1); truncated drafts may accept little
    # on a random-init model but must still never corrupt the stream
    if rank == 0:
        assert rep.acceptance_rate == 1.0
        assert rep.decode_steps < base_rep.decode_steps
        assert rep.tokens_per_forward > 1.3
    # every block returned after the rollback churn
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


@pytest.mark.parametrize("eviction", ["recompute", "swap"])
def test_speculative_survives_preemption(tiny_elite_cfg, tiny_elite_model,
                                         eviction, stress_blocks):
    """Tiny pool → verify-window growth forces preemptions mid-flight (the
    window allocates k+1 slots at once, so pressure is *worse* than plain
    decode); evicted lanes recompute/swap their prefix and the streams still
    match plain decode on an ample pool."""
    params, buffers = tiny_elite_model
    base, _, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=64)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           num_blocks=stress_blocks(9), spec_k=2, rank=16,
                           eviction=eviction)
    assert out == base
    assert rep.preemptions > 0            # the tiny pool really forced them
    if eviction == "swap":
        assert rep.swap_outs > 0 and rep.swap_ins == rep.swap_outs
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


def test_full_rank_sampled_matches_plain(tiny_elite_cfg, tiny_elite_model):
    """Draft == target ⇒ rejection sampling accepts everything and the
    seeded temperature/top-p stream equals plain decode: proposals use the
    same count-folded PRNG the plain sampler would, and the bonus token is
    drawn from the verify logits with the same fold."""
    params, buffers = tiny_elite_model
    base, _, _ = _run(params, buffers, tiny_elite_cfg, temp=0.8)
    out, rep, _ = _run(params, buffers, tiny_elite_cfg, spec_k=2, rank=0,
                       temp=0.8)
    assert out == base
    assert rep.acceptance_rate == 1.0
    assert rep.draft_proposed > 0


def test_truncated_sampled_is_well_formed(tiny_elite_cfg, tiny_elite_model):
    """Truncated-draft sampled decode: the *path* may diverge from plain
    (rejection sampling preserves the distribution, not the sample path) but
    every request must complete with a full budget-or-EOS stream and the
    accounting must be conserved."""
    params, buffers = tiny_elite_model
    out, rep, sched = _run(params, buffers, tiny_elite_cfg, spec_k=3, rank=16,
                           temp=0.9)
    assert rep.completed == 4
    assert all(len(t) == 10 for t in out.values())     # budget streams
    assert 0 <= rep.draft_accepted <= rep.draft_proposed
    assert 1.0 <= rep.tokens_per_forward <= 4.0        # ∈ [1, k+1]
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


def test_speculative_with_eos_mid_window(tiny_elite_cfg, tiny_elite_model):
    """A token id declared EOS can land inside an accepted window; the
    stream must cut exactly where plain decode's would."""
    params, buffers = tiny_elite_model
    # pick the EOS id from the plain run so it actually triggers mid-stream
    base, _, _ = _run(params, buffers, tiny_elite_cfg)
    eos = next(iter(base.values()))[4]    # 5th token of request 0's stream
    base_eos, base_rep, _ = _run(params, buffers, tiny_elite_cfg, eos_id=eos)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg, spec_k=4, rank=0,
                           eos_id=eos)
    assert out == base_eos
    assert any(r.finish_reason == "eos" for r in sched.finished)
    assert sched.pool.allocator.num_free == sched.pool.num_blocks


def test_speculative_oneshot_prefill_mode(tiny_elite_cfg, tiny_elite_model,
                                          stress_blocks):
    """chunk=0 (whole-prompt admission prefill) composes with speculative
    decode — the draft/verify path only ever sees decode-ready lanes."""
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    base, _, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=nb, chunk=0)
    out, _, _ = _run(params, buffers, tiny_elite_cfg, num_blocks=nb, chunk=0,
                     spec_k=2, rank=16)
    assert out == base


# ---------------------------------------------------------------------------
# mechanism: rollback conservation + draft weights
# ---------------------------------------------------------------------------

def test_pool_truncate_frees_tail_blocks(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    pool.ensure_capacity(0, 15)           # 4 blocks
    assert pool.allocator.num_used == 4
    chain = pool.block_table(0)
    pool.truncate(0, 9)                   # 3 blocks keep the 9 tokens
    assert pool.length(0) == 9
    assert pool.allocator.num_used == 3
    assert pool.block_table(0) == chain[:3]
    pool.truncate(0, 9)                   # idempotent at the same length
    assert pool.allocator.num_used == 3
    pool.truncate(0, 0)                   # empty chain stays registered
    assert pool.allocator.num_used == 0 and pool.length(0) == 0
    with pytest.raises(AssertionError):
        pool.truncate(0, 5)               # growth is not truncate's job


def test_make_draft_params_identity_and_truncation(tiny_elite_cfg,
                                                   tiny_elite_model):
    params, _ = tiny_elite_model
    cfg = tiny_elite_cfg
    # full-rank requests return the SAME object (no copy, shared jit cache)
    assert lm.make_draft_params(params, cfg, 0) is params
    assert lm.make_draft_params(params, cfg, cfg.elitekv.d_ckv) is params
    rank = 8
    draft = lm.make_draft_params(params, cfg, rank)
    bk = np.asarray(params["blocks"]["p0"]["attn"]["bk"])
    bk_d = np.asarray(draft["blocks"]["p0"]["attn"]["bk"])
    assert bk_d.shape == bk.shape
    assert not np.allclose(bk_d, bk)      # truncation really changed them
    # rank bound: every layer's stacked [bk | bv] factor has rank <= rank
    bv_d = np.asarray(draft["blocks"]["p0"]["attn"]["bv"])
    for s in range(bk_d.shape[0]):
        M = np.concatenate([bk_d[s].reshape(bk_d.shape[1], -1),
                            bv_d[s].reshape(bv_d.shape[1], -1)], axis=1)
        assert np.linalg.matrix_rank(M, tol=1e-4) <= rank
    # everything else is untouched (shared latent write path)
    np.testing.assert_array_equal(
        np.asarray(draft["blocks"]["p0"]["attn"]["a_kv"]),
        np.asarray(params["blocks"]["p0"]["attn"]["a_kv"]))
    np.testing.assert_array_equal(np.asarray(draft["embed"]["table"]),
                                  np.asarray(params["embed"]["table"]))


# ---------------------------------------------------------------------------
# benchmark workload seeding regression
# ---------------------------------------------------------------------------

def test_serving_workload_is_deterministic():
    """Two benchmark invocations must build the identical request set —
    prompts, arrivals, budgets AND per-request sample seeds — so the
    speculative-vs-plain comparison rows are token-comparable."""
    from benchmarks.run import serving_workload
    a = serving_workload(2.0)
    b = serving_workload(2.0)
    assert len(a) == len(b) == 12
    for ra, rb in zip(a, b):
        assert ra.uid == rb.uid
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.arrival == rb.arrival
        assert ra.max_new_tokens == rb.max_new_tokens
        assert (ra.temperature, ra.top_p, ra.seed) == \
            (rb.temperature, rb.top_p, rb.seed)
    # seeds are pinned per request (not left at the shared default)
    assert len({r.seed for r in a}) == len(a)
