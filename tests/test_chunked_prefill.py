"""Chunked prefill + per-request sampling for the paged serving loop.

Golden invariant: splitting a prompt's prefill into chunks — any chunk size,
dividing the prompt length or not, aligned with pool blocks or not — must
produce *token-identical* greedy output to one-shot prefill, because resumed
chunks attend to the exact cached prefix through the block table.  Sampling
tier: per-request seeds are reproducible, temperature 0 reduces exactly to
greedy, and top-p truncation is verifiable at the sampler level.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import PagedKVPool
from repro.models import lm
from repro.runtime import serve_loop


def _run_stream(params, buffers, cfg, chunk, *, temp=0.0, top_p=1.0,
                seeds=None, n_req=4, max_new=6, block_size=4, seed=3,
                num_blocks=64, lanes=1, max_slots=2, arrival_gap=0.7):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=block_size, num_blocks=num_blocks,
        max_len=48, prefill_bucket=4, prefill_chunk_tokens=chunk,
        prefill_batch_lanes=lanes)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    rng = np.random.default_rng(seed)
    reqs = [serve_loop.Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(5, 18))).astype(np.int32),
        max_new_tokens=max_new, arrival=i * arrival_gap,
        temperature=temp, top_p=top_p,
        seed=(seeds[i] if seeds else 0)) for i in range(n_req)]
    report = sched.run(reqs)
    return {r.uid: list(r.generated) for r in sched.finished}, report


# ---------------------------------------------------------------------------
# chunked == one-shot (the acceptance invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [
    4,          # == block_size: every chunk boundary is a block boundary
    5,          # divides neither the prompts nor the pool blocks
    32,         # >= every prompt: degenerates to one chunk
])
def test_chunked_prefill_token_parity(tiny_elite_cfg, tiny_elite_model, chunk,
                                      stress_blocks):
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    base, base_rep = _run_stream(params, buffers, tiny_elite_cfg, 0,
                                 num_blocks=nb)
    out, rep = _run_stream(params, buffers, tiny_elite_cfg, chunk,
                           num_blocks=nb)
    assert out == base
    assert rep.completed == base_rep.completed == 4
    # chunking really split the work (except the degenerate full-prompt size)
    if chunk < 18:
        assert rep.prefill_chunks > base_rep.prefill_chunks


# ---------------------------------------------------------------------------
# batched multi-sequence prefill == one-request-per-chunk (PR-3 path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [
    4,          # == block_size: chunk boundaries land on block boundaries
    5,          # divides neither the prompts nor the pool blocks
    8,          # 2 blocks per chunk
])
@pytest.mark.parametrize("lanes", [2, 3])
def test_batched_prefill_token_parity(tiny_elite_cfg, tiny_elite_model, chunk,
                                      lanes, stress_blocks):
    """N requests' chunks packed into one forward (per-lane chunk_start /
    prefix_lens vectors) must generate the same tokens as the single-lane
    path AND as one-shot prefill — simultaneous arrivals force multiple
    mid-prefill lanes to coexist, so chunks of different sequences really
    share forwards."""
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    kw = dict(n_req=5, max_slots=3, arrival_gap=0.0, num_blocks=nb)
    base, _ = _run_stream(params, buffers, tiny_elite_cfg, 0, **kw)
    single, rep1 = _run_stream(params, buffers, tiny_elite_cfg, chunk,
                               lanes=1, **kw)
    packed, repn = _run_stream(params, buffers, tiny_elite_cfg, chunk,
                               lanes=lanes, **kw)
    assert packed == single == base
    assert repn.mean_prefill_batch > 1.0       # packing actually happened
    assert rep1.mean_prefill_batch == 1.0
    # packing several lanes per forward issues fewer prefill calls
    assert repn.prefill_chunks < rep1.prefill_chunks


def test_batched_prefill_sampling_parity(tiny_elite_cfg, tiny_elite_model):
    """Per-request seeded sampling is invariant to prefill packing: the PRNG
    is keyed on (seed, token index), never on lane or forward composition."""
    params, buffers = tiny_elite_model
    seeds = [7, 8, 9, 10, 11]
    kw = dict(n_req=5, max_slots=3, arrival_gap=0.0, temp=0.9, top_p=0.8,
              seeds=seeds)
    single, _ = _run_stream(params, buffers, tiny_elite_cfg, 5, lanes=1, **kw)
    packed, rep = _run_stream(params, buffers, tiny_elite_cfg, 5, lanes=3, **kw)
    assert packed == single
    assert rep.mean_prefill_batch > 1.0


def test_chunk_equal_to_block_crosses_boundaries(tiny_elite_cfg, tiny_elite_model):
    """Prompt of exactly 3 blocks, chunk == block: every resumed chunk starts
    on a block boundary and the prefix gather walks whole blocks."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    bs = 4
    prompt = (np.arange(3 * bs) * 7 % cfg.vocab_size).astype(np.int32)

    def run(chunk):
        scfg = serve_loop.SchedulerConfig(
            max_slots=1, block_size=bs, num_blocks=16, max_len=32,
            prefill_bucket=4, prefill_chunk_tokens=chunk)
        sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
        sched.run([serve_loop.Request(uid=0, prompt=prompt.copy(),
                                      max_new_tokens=5)])
        return sched.finished[0].generated

    assert run(bs) == run(0)


def test_chunked_pages_match_oneshot(tiny_elite_cfg, tiny_elite_model):
    """The pool pages a chunked prefill writes are identical to one-shot's
    on every slot the sequence owns (scatter windows cover each position
    exactly once)."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    sp, bs, mb = 11, 4, 8
    prompt = (np.arange(sp) * 5 % cfg.vocab_size).astype(np.int32)

    def prefill(chunk):
        pool = PagedKVPool(cfg, num_blocks=16, block_size=bs)
        pool.ensure_capacity(0, sp)
        pages = pool.pages
        start = 0
        while start < sp:
            n = min(chunk, sp - start)
            toks = np.zeros((1, chunk), np.int32)
            toks[0, :n] = prompt[start:start + n]
            sm = pool.prefill_slot_mapping(0, start, n, chunk)[None]
            if start == 0:
                _, pages = lm.apply_prefill_paged(
                    params, buffers, cfg, {"tokens": jnp.asarray(toks)},
                    pages, jnp.asarray(sm))
            else:
                _, pages = lm.apply_prefill_paged(
                    params, buffers, cfg, {"tokens": jnp.asarray(toks)},
                    pages, jnp.asarray(sm),
                    chunk_start=jnp.asarray(start, jnp.int32),
                    block_tables=jnp.asarray(pool.block_table_array([0], mb)),
                    prefix_lens=jnp.asarray([start], jnp.int32),
                    block_size=bs)
            start += n
        owned = [b * bs + i for b in pool.block_table(0) for i in range(bs)]
        return pages, sorted(owned)[:sp]

    pages_one, owned = prefill(sp)
    pages_chunk, owned2 = prefill(3)
    assert owned == owned2
    k1 = np.asarray(pages_one["p0"]["k_e"][0])[owned]
    k2 = np.asarray(pages_chunk["p0"]["k_e"][0])[owned]
    np.testing.assert_allclose(k1, k2, atol=1e-6, rtol=1e-6)
    c1 = np.asarray(pages_one["p0"]["c"][0])[owned]
    c2 = np.asarray(pages_chunk["p0"]["c"][0])[owned]
    np.testing.assert_allclose(c1, c2, atol=1e-6, rtol=1e-6)


def test_chunked_prefill_interleaves_with_decode(tiny_elite_cfg, tiny_elite_model):
    """A long prompt arriving while a short request decodes must not stall
    it: the resident keeps producing tokens during the newcomer's chunked
    prefill, and the newcomer's prefill spans multiple scheduler steps."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    scfg = serve_loop.SchedulerConfig(
        max_slots=2, block_size=4, num_blocks=64, max_len=48,
        prefill_bucket=4, prefill_chunk_tokens=4)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    short = serve_loop.Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=12, arrival=0.0)
    long_ = serve_loop.Request(
        uid=1, prompt=(np.arange(20) % cfg.vocab_size).astype(np.int32),
        max_new_tokens=4, arrival=1.0)
    sched.submit(short)
    sched.submit(long_)
    tokens_during_prefill = 0
    while sched.step():
        if (sched.slots.count(None) < 2 and long_.prefill_pos < 20
                and long_.arrival <= sched.t):
            tokens_during_prefill = len(short.generated)
    assert len(sched.finished) == 2
    # 20 prompt tokens / 4-token chunks ⇒ 5 chunk steps, the first at arrival
    assert long_.first_token_step - long_.arrival >= 4
    # the resident short request kept decoding while the long prompt prefilled
    assert tokens_during_prefill > 1


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sampling_same_seed_reproduces(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    seeds = [11, 22, 33, 44]
    a, _ = _run_stream(params, buffers, tiny_elite_cfg, 4, temp=1.0, seeds=seeds)
    b, _ = _run_stream(params, buffers, tiny_elite_cfg, 4, temp=1.0, seeds=seeds)
    assert a == b
    c, _ = _run_stream(params, buffers, tiny_elite_cfg, 4, temp=1.0,
                       seeds=[s + 100 for s in seeds])
    assert a != c                         # different seeds explore differently


def test_temperature_zero_is_greedy(tiny_elite_cfg, tiny_elite_model):
    """temperature=0 with any seed must equal the pure-greedy run — the
    sampler collapses to argmax, not to a sharpened distribution."""
    params, buffers = tiny_elite_model
    greedy, _ = _run_stream(params, buffers, tiny_elite_cfg, 0)
    cold, _ = _run_stream(params, buffers, tiny_elite_cfg, 0,
                          temp=0.0, seeds=[5, 6, 7, 8])
    assert cold == greedy


def test_sample_tokens_unit():
    """Sampler semantics on a hand-built distribution."""
    logits = jnp.asarray([[0.0, 3.0, 1.0, -2.0]] * 3)
    temps = jnp.asarray([0.0, 1.0, 1.0], jnp.float32)
    # lane 1: top_p tiny → nucleus is exactly the argmax token
    # lane 2: full nucleus, free to sample
    top_ps = jnp.asarray([1.0, 1e-4, 1.0], jnp.float32)
    seeds = jnp.asarray([0, 1, 2], jnp.int32)
    counts = jnp.asarray([0, 0, 0], jnp.int32)
    toks = np.asarray(serve_loop.sample_tokens(logits, temps, top_ps, seeds,
                                               counts))
    assert toks[0] == 1 and toks[1] == 1
    assert 0 <= toks[2] < 4
    # reproducible: same key → same draw; folded key moves on
    again = np.asarray(serve_loop.sample_tokens(logits, temps, top_ps, seeds,
                                                counts))
    np.testing.assert_array_equal(toks, again)
    draws = [int(np.asarray(serve_loop.sample_tokens(
        logits[2:], temps[2:], top_ps[2:], seeds[2:],
        jnp.asarray([c], jnp.int32)))[0]) for c in range(20)]
    assert len(set(draws)) > 1            # the token-index fold matters


def test_ttft_bucket_helper():
    def req(sp, ttft):
        r = serve_loop.Request(uid=0, prompt=np.zeros(sp, np.int32),
                               max_new_tokens=1, arrival=0.0)
        r.first_token_step = ttft
        return r

    buckets = serve_loop.ttft_by_prompt_bucket(
        [req(4, 2), req(8, 4), req(30, 10), req(100, 20)], edges=(16, 64))
    assert buckets == {"1-16": 3.0, "17-64": 10.0, ">64": 20.0}
