"""Cross-request prefix caching: the golden wall and the mechanism.

Golden tier: turning the prefix cache ON must be *invisible in the token
stream* — greedy and seeded-sampled output is identical to cache-off for
every prefill chunking, for prompts ending exactly on / around block
boundaries, under tiny-pool preemption (recompute and swap), and under
speculative decode whose rejected verify windows roll back through shared
prefix blocks.  Mechanism tier: chained-hash determinism and parent
dependence, the partial-tail exclusion, LRU retention/reclaim order,
copy-on-write content isolation, refcount-aware truncate, and the
ServeReport / benchmark-workload accounting.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import (BlockManager, PagedKVPool, PrefixCache,
                              block_hash, prefix_block_hashes, _HASH_ROOT)
from repro.models import lm
from repro.runtime import serve_loop


def _shared_workload(cfg, n_req=5, shared=12, seed=7, temp=0.0, max_new=8,
                     suffixes=None):
    """``n_req`` requests sharing a ``shared``-token system prefix, each with
    a small unique suffix (``suffixes`` overrides the per-request lengths —
    0 means the prompt IS the bare shared prefix).  Staggered arrivals give
    the first resident time to register its blocks before later lookups."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
    reqs = []
    for i in range(n_req):
        n_suf = (suffixes[i] if suffixes is not None
                 else int(rng.integers(2, 6)))
        tail = rng.integers(0, cfg.vocab_size, n_suf).astype(np.int32)
        reqs.append(serve_loop.Request(
            uid=i, prompt=np.concatenate([head, tail]),
            max_new_tokens=max_new, arrival=i * 0.5,
            temperature=temp, top_p=0.9, seed=11 + i))
    return reqs


def _run(params, buffers, cfg, workload, *, prefix_cache, num_blocks=64,
         admission="preempt", eviction="recompute", chunk=4, max_slots=2,
         spec_k=0, rank=0):
    scfg = serve_loop.SchedulerConfig(
        max_slots=max_slots, block_size=4, num_blocks=num_blocks, max_len=48,
        prefill_bucket=4, prefill_chunk_tokens=chunk,
        admission=admission, eviction=eviction,
        speculate_k=spec_k, draft_rank=rank, prefix_cache=prefix_cache)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    report = sched.run(workload)
    return {r.uid: list(r.generated) for r in sched.finished}, report, sched


def _drained(sched):
    """Pool conservation after the stream drains: every block is either on
    the free list or LRU-retained by the cache — nothing leaked."""
    retained = sched.bm.prefix.num_retained if sched.bm.prefix else 0
    return sched.pool.allocator.num_free + retained == sched.pool.num_blocks


# ---------------------------------------------------------------------------
# golden invariant: the cache never changes tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [0, 6])
@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_cache_on_matches_off(tiny_elite_cfg, tiny_elite_model, chunk, temp):
    """Greedy and seeded-sampled streams are bit-identical with the cache on,
    for both one-shot (chunk=0) and chunked prefill — a hit only skips
    recomputation of pages whose content is already exact."""
    params, buffers = tiny_elite_model
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             _shared_workload(tiny_elite_cfg, temp=temp),
                             prefix_cache=False, chunk=chunk)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _shared_workload(tiny_elite_cfg, temp=temp),
                           prefix_cache=True, chunk=chunk)
    assert out == base
    assert rep.completed == base_rep.completed == 5
    assert rep.prefix_cache_hits > 0 and rep.prefix_cache_hit_tokens > 0
    assert base_rep.prefix_cache_hits == base_rep.prefix_cache_hit_tokens == 0
    assert _drained(sched)


def test_block_boundary_prompt_lengths(tiny_elite_cfg, tiny_elite_model):
    """Prompts ending exactly on a block boundary, one past it, and one short
    of the next — including a full duplicate of the shared prefix (suffix 0):
    the hit cap must always leave the final prompt token to re-prefill so the
    first-token logits exist, and streams still match cache-off."""
    params, buffers = tiny_elite_model
    suffixes = [0, 1, 3, 4, 0]       # prompt lens 12, 13, 15, 16, 12 (bs=4)
    wl = lambda: _shared_workload(tiny_elite_cfg, suffixes=suffixes)
    base, _, _ = _run(params, buffers, tiny_elite_cfg, wl(),
                      prefix_cache=False)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg, wl(),
                           prefix_cache=True)
    assert out == base
    assert rep.prefix_cache_hits > 0
    # every request re-prefilled at least its final prompt token
    assert all(r.prefix_hit_tokens < len(r.prompt) for r in sched.finished)
    assert _drained(sched)


@pytest.mark.parametrize("eviction", ["recompute", "swap"])
def test_preemption_with_prefix_cache(tiny_elite_cfg, tiny_elite_model,
                                      eviction, stress_blocks):
    """Tiny pool → forced preemptions while prefixes are shared: eviction
    must never free or roll back a block another chain references, and the
    streams still equal the ample-pool cache-off baseline."""
    params, buffers = tiny_elite_model
    base, base_rep, _ = _run(params, buffers, tiny_elite_cfg,
                             _shared_workload(tiny_elite_cfg),
                             prefix_cache=False, num_blocks=64,
                             admission="watermark")
    assert base_rep.preemptions == 0
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _shared_workload(tiny_elite_cfg),
                           prefix_cache=True,
                           num_blocks=stress_blocks(10), eviction=eviction)
    assert out == base
    assert rep.preemptions > 0
    assert _drained(sched)


def test_speculative_with_prefix_cache(tiny_elite_cfg, tiny_elite_model,
                                       stress_blocks):
    """Speculative decode over shared prefixes: a rejected verify window
    truncates the chain mid-macro-step — the rollback must un-link, never
    free, blocks other chains still read, and greedy streams stay identical
    to plain cache-off decode (truncated draft rank forces real rejections)."""
    params, buffers = tiny_elite_model
    nb = stress_blocks(64)
    base, _, _ = _run(params, buffers, tiny_elite_cfg,
                      _shared_workload(tiny_elite_cfg),
                      prefix_cache=False, num_blocks=nb)
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _shared_workload(tiny_elite_cfg),
                           prefix_cache=True, num_blocks=nb,
                           spec_k=2, rank=16)
    assert out == base
    assert rep.draft_forwards > 0
    assert rep.prefix_cache_hits > 0
    assert _drained(sched)


# ---------------------------------------------------------------------------
# hash chain: determinism, parent dependence, partial-tail exclusion
# ---------------------------------------------------------------------------

def test_hash_chain_deterministic():
    toks = np.arange(13, dtype=np.int32)
    a = prefix_block_hashes(toks, 4)
    b = prefix_block_hashes(toks.copy(), 4)
    assert a == b and len(a) == 3            # 13 tokens → 3 full blocks
    # growing into the partial tail never perturbs existing block hashes
    assert prefix_block_hashes(toks[:15], 4) == a
    # the chain is incremental: hash i is reproducible from hash i-1
    assert a[2] == block_hash(a[1], toks[8:12])
    assert a[0] == block_hash(_HASH_ROOT, toks[:4])


def test_hash_parent_dependence():
    """Identical block-i tokens with different earlier tokens must produce
    different block-i hashes — content-equality of one block is not enough."""
    x = np.arange(8, dtype=np.int32)
    y = x.copy()
    y[0] += 1                                 # differs only in block 0
    hx, hy = prefix_block_hashes(x, 4), prefix_block_hashes(y, 4)
    assert hx[0] != hy[0]
    assert hx[1] != hy[1]                     # chained: block 1 diverges too
    assert np.array_equal(x[4:], y[4:])       # …despite identical tokens


def test_partial_tail_never_hashed():
    assert prefix_block_hashes(np.arange(3, dtype=np.int32), 4) == []
    toks = np.arange(10, dtype=np.int32)
    assert len(prefix_block_hashes(toks, 4)) == 2   # tail 8:10 uncovered


def test_register_skips_partial_tail(tiny_elite_cfg):
    """A chain 10 tokens long with block_size 4 claims exactly its 2 full
    blocks; the partially-written third block stays uncached."""
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    bm = BlockManager(pool, prefix_cache=True)
    bm.grow(0, 10)
    toks = np.arange(10, dtype=np.int32)
    assert bm.register_prefix(0, toks) == 2
    assert bm.prefix.num_cached == 2
    table = pool.block_table(0)
    assert bm.prefix.is_cached(table[0]) and bm.prefix.is_cached(table[1])
    assert not bm.prefix.is_cached(table[2])


def test_lookup_caps_final_token(tiny_elite_cfg):
    """Even a fully-cached identical prompt re-prefills its last token: the
    hit is capped at (len-1)//block_size blocks so the first-token logits
    row exists."""
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    bm = BlockManager(pool, prefix_cache=True)
    toks = np.arange(12, dtype=np.int32)
    bm.grow(0, 12)
    assert bm.register_prefix(0, toks) == 3
    assert bm.lookup_prefix(1, toks) == 8            # not 12
    assert bm.lookup_prefix(2, np.arange(13, dtype=np.int32)) == 12
    assert pool._refcount[pool.block_table(0)[0]] == 3


# ---------------------------------------------------------------------------
# LRU retention and claim semantics
# ---------------------------------------------------------------------------

def test_lru_retention_eviction_order():
    """Reclaim pops the least-recently-used retained block first, and
    re-retaining refreshes recency."""
    pc = PrefixCache()
    for b in (1, 2, 3):
        assert pc.claim(bytes([b]) * 32, b)
        assert pc.retain(b)
    pc.retain(1)                              # refresh: 1 becomes newest
    assert pc.reclaim(2) == [2, 3]            # oldest first, 1 survives
    assert pc.num_retained == 1 and pc.num_cached == 1
    assert pc.reclaim(5) == [1]               # reclaim is capped by supply
    assert pc.num_retained == pc.num_cached == 0
    assert pc.reclaimed == 3


def test_first_claim_wins():
    pc = PrefixCache()
    h1, h2 = b"a" * 32, b"b" * 32
    assert pc.claim(h1, 7)
    assert not pc.claim(h1, 8)                # duplicate hash keeps block 7
    assert not pc.claim(h2, 7)                # block already claimed
    assert pc.get(h1) == 7 and pc.get(h2) is None
    pc.invalidate(7)
    assert pc.get(h1) is None and pc.num_cached == 0


def test_lookup_refreshes_lru(tiny_elite_cfg):
    """A retained block served to a lookup leaves the reclaimable LRU; the
    allocator can no longer steal it out from under its new reader."""
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=4, block_size=4)
    bm = BlockManager(pool, prefix_cache=True)
    toks = np.arange(8, dtype=np.int32)
    bm.grow(0, 8)
    bm.register_prefix(0, toks)
    bm.release(0)                             # both blocks retire to the LRU
    assert bm.prefix.num_retained == 2
    assert bm.lookup_prefix(1, np.arange(9, dtype=np.int32)) == 8
    assert bm.prefix.num_retained == 0        # back in a chain, off the LRU
    # exhaust the pool: the shared blocks must never be reclaimed
    bm.grow(2, 8)
    shared = set(pool.block_table(1))
    assert shared.isdisjoint(pool.block_table(2))
    assert bm.prefix.reclaimed == 0


# ---------------------------------------------------------------------------
# copy-on-write and refcount-aware truncate
# ---------------------------------------------------------------------------

def test_cow_preserves_reader_content(tiny_elite_cfg, tiny_elite_model):
    """A writer into a shared block gets a private copy with the content
    carried over; the reader's block, its pages, and its cache claim are
    untouched."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    bs, sp = 4, 8
    pool = PagedKVPool(cfg, num_blocks=8, block_size=bs)
    bm = BlockManager(pool, prefix_cache=True)
    toks = np.arange(sp, dtype=np.int32) % cfg.vocab_size
    pool.ensure_capacity(0, sp)
    padded = np.zeros((1, sp), np.int32)
    padded[0] = toks
    sm = pool.prefill_slot_mapping(0, 0, sp, sp)[None]
    _, pool.pages = lm.apply_prefill_paged(
        params, buffers, cfg, {"tokens": jnp.asarray(padded)}, pool.pages,
        jnp.asarray(sm))
    bm.register_prefix(0, toks)
    assert bm.lookup_prefix(1, toks) == 4     # seq 1 shares block 0
    b0 = pool.block_table(0)[0]
    assert pool.block_table(1) == [b0] and pool._refcount[b0] == 2

    def content(block):
        slots = np.arange(block * bs, (block + 1) * bs)
        return np.asarray(pool.pages["p0"]["k_e"])[:, slots].copy()

    before = content(b0)
    bm.prepare_write(1, 0, 4)                 # seq 1 is about to scatter
    new = pool.block_table(1)[0]
    assert new != b0, "writer must repoint to a private copy"
    assert pool.cow_copies == 1
    assert pool._refcount[b0] == 1 and pool._refcount[new] == 1
    assert bm.prefix.is_cached(b0) and not bm.prefix.is_cached(new)
    np.testing.assert_array_equal(content(b0), before)     # reader untouched
    np.testing.assert_array_equal(content(new), before)    # content carried


def test_truncate_shared_block_unlinks_not_frees(tiny_elite_cfg):
    """Rolling one chain back through a shared block un-links it from that
    chain only: the other reader keeps it, it never touches the free list,
    and nothing rolls back."""
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    bm = BlockManager(pool, prefix_cache=True)
    toks = np.arange(12, dtype=np.int32)
    bm.grow(0, 12)
    bm.register_prefix(0, toks)
    assert bm.lookup_prefix(1, toks) == 8     # shares blocks a, b
    a, b = pool.block_table(1)
    free_before = pool.allocator.num_free
    bm.truncate(1, 0)                         # roll the sharer all the way back
    assert pool.block_table(1) == []
    assert pool.block_table(0) == [a, b, pool.block_table(0)[2]]
    assert pool._refcount[a] == pool._refcount[b] == 1
    assert pool.allocator.num_free == free_before   # nothing freed
    assert bm.prefix.num_retained == 0        # still referenced by seq 0
    # now the sole owner retires: cached blocks retain instead of freeing
    bm.release(0)
    assert bm.prefix.num_retained == 3
    assert pool.allocator.num_free == free_before


def test_truncate_single_owner_still_frees(tiny_elite_cfg):
    """Regression for the pre-cache path: an exclusively-owned, uncached
    tail block goes straight back to the allocator on truncate."""
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    bm = BlockManager(pool)                   # no prefix cache
    bm.grow(0, 12)
    assert pool.allocator.num_free == 5
    bm.truncate(0, 5)                         # drop blocks 2 and 3… keep 0,1
    assert pool.allocator.num_free == 6
    assert len(pool.block_table(0)) == 2 and pool.length(0) == 5
    bm.truncate(0, 0)
    assert pool.allocator.num_free == 8 and pool.block_table(0) == []


# ---------------------------------------------------------------------------
# accounting: ServeReport fields and the benchmark workload
# ---------------------------------------------------------------------------

def test_serve_report_prefix_fields(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    out, rep, sched = _run(params, buffers, tiny_elite_cfg,
                           _shared_workload(tiny_elite_cfg),
                           prefix_cache=True)
    assert rep.prefix_cache is True
    assert rep.prefix_cache_hits + rep.prefix_cache_misses > 0
    assert 0.0 < rep.prefix_cache_hit_rate <= 1.0
    assert rep.prefix_cache_hit_tokens == \
        sum(r.prefix_hit_tokens for r in sched.finished)
    assert rep.cow_copies == sched.pool.cow_copies >= 0
    assert rep.blocks_retained == sched.bm.prefix.num_retained
    assert "pc[" in rep.summary()
    _, off, _ = _run(params, buffers, tiny_elite_cfg,
                     _shared_workload(tiny_elite_cfg), prefix_cache=False)
    assert off.prefix_cache is False
    assert off.prefix_cache_hit_rate == 0.0 and off.cow_copies == 0
    assert "pc[" not in off.summary()


def test_shared_prefix_workload_deterministic():
    from benchmarks.run import shared_prefix_workload
    a = shared_prefix_workload()
    b = shared_prefix_workload()
    assert len(a) == len(b) == 10
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)
        assert (ra.uid, ra.arrival, ra.seed, ra.temperature) == \
            (rb.uid, rb.arrival, rb.seed, rb.temperature)
    # 9 of 10 share the documented system prefix; one control does not
    head = a[0].prompt[:64]
    sharers = [r for r in a if len(r.prompt) >= 64
               and np.array_equal(r.prompt[:64], head)]
    assert len(sharers) == 9
