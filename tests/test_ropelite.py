"""RoPElite greedy search: validity, optimality vs baselines, brute-force check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ropelite
from repro.configs import make_inputs
from repro.models import lm


@pytest.fixture(scope="module")
def searched(tiny_cfg, tiny_model):
    params, buffers = tiny_model
    batch = make_inputs(tiny_cfg, 2, 24, "train", seed=3)
    sets = {m: ropelite.search_model(params, buffers, tiny_cfg, batch, r=4, method=m)
            for m in ("greedy", "uniform", "contribution")}
    return sets, batch


def test_sets_valid(searched, tiny_cfg):
    sets, _ = searched
    C = tiny_cfg.head_dim // 2
    for m, per_layer in sets.items():
        assert sorted(per_layer.keys()) == list(range(tiny_cfg.num_layers))
        for li, idx in per_layer.items():
            idx = np.asarray(idx)
            assert idx.shape == (tiny_cfg.n_kv_heads, 4)
            assert idx.min() >= 0 and idx.max() < C
            for h in range(idx.shape[0]):
                assert len(set(idx[h].tolist())) == 4, f"dup chunks {m} L{li}"


def _layer_distance(tiny_cfg, tiny_model, batch, elite_idx, layer=0):
    params, buffers = tiny_model
    caps = lm.capture_attn_inputs(params, buffers, tiny_cfg, batch)
    x = caps["p0"][layer]
    lp = jax.tree.map(lambda t: t[layer], params["blocks"]["p0"]["attn"])
    q, k = ropelite._layer_qk(lp, tiny_cfg, x)
    pos = jnp.arange(x.shape[1])
    return float(ropelite.score_distance(
        q, k, pos, tiny_cfg.rope_theta, tiny_cfg.q_group, elite_idx).sum())


def test_greedy_beats_baselines(searched, tiny_cfg, tiny_model):
    """Paper Table 2 mechanism: greedy < {contribution, uniform} on ‖Δs‖₁."""
    sets, batch = searched
    d = {m: _layer_distance(tiny_cfg, tiny_model, batch, sets[m][0])
         for m in sets}
    assert d["greedy"] <= d["contribution"] * 1.001
    assert d["greedy"] <= d["uniform"] * 1.001


def test_greedy_first_pick_is_bruteforce_argmin(tiny_cfg, tiny_model):
    """r=1 greedy == exhaustive search over single chunks (per KV head)."""
    params, buffers = tiny_model
    batch = make_inputs(tiny_cfg, 1, 16, "train", seed=7)
    caps = lm.capture_attn_inputs(params, buffers, tiny_cfg, batch)
    x = caps["p0"][0]
    lp = jax.tree.map(lambda t: t[0], params["blocks"]["p0"]["attn"])
    q, k = ropelite._layer_qk(lp, tiny_cfg, x)
    pos = jnp.arange(x.shape[1])
    got = ropelite.greedy_search_layer(q, k, pos, tiny_cfg.rope_theta,
                                       tiny_cfg.q_group, r=1)
    C = tiny_cfg.head_dim // 2
    dists = np.stack([
        np.asarray(ropelite.score_distance(
            q, k, pos, tiny_cfg.rope_theta, tiny_cfg.q_group,
            jnp.full((tiny_cfg.n_kv_heads, 1), c, jnp.int32)))
        for c in range(C)])                                   # [C, nkv]
    brute = dists.argmin(axis=0)
    np.testing.assert_array_equal(np.asarray(got)[:, 0], brute)


@pytest.mark.slow
def test_greedy_distance_decreases_with_r(tiny_cfg, tiny_model):
    params, buffers = tiny_model
    batch = make_inputs(tiny_cfg, 1, 16, "train", seed=9)
    prev = None
    for r in (1, 2, 4):
        sets = ropelite.search_model(params, buffers, tiny_cfg, batch, r=r)
        d = _layer_distance(tiny_cfg, tiny_model, batch, sets[0])
        if prev is not None:
            assert d <= prev * 1.001, f"distance increased at r={r}"
        prev = d


def test_uniform_selection_shape():
    sel = ropelite.uniform_selection(16, 4, 3)
    assert sel.shape == (3, 4)
    assert len(set(np.asarray(sel)[0].tolist())) == 4
