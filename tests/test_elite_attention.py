"""EliteKV attention invariants: absorbed decode ≡ materialized; cache stores
post-rotation keys; prefill+decode ≡ full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_inputs
from repro.configs.base import EliteKVConfig
from repro.models import lm


def _roundtrip(cfg, params, buffers, batch, B, S, split, **kw):
    logits_full, _ = lm.apply_train(params, buffers, cfg, batch, **kw)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    lp, cache = lm.apply_prefill(params, buffers, cfg,
                                 {"tokens": batch["tokens"][:, :split]}, cache, **kw)
    errs = [float(jnp.max(jnp.abs(lp - logits_full[:, :split])))]
    for t in range(split, S):
        ld, cache = lm.apply_decode(params, buffers, cfg,
                                    {"tokens": batch["tokens"][:, t:t + 1]}, cache, **kw)
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - logits_full[:, t]))))
    return max(errs)


def test_decode_equals_train_jlrd(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    B, S = 2, 20
    batch = make_inputs(tiny_elite_cfg, B, S, "train", seed=5)
    assert _roundtrip(tiny_elite_cfg, params, buffers, batch, B, S, 12) < 2e-5


def test_decode_equals_train_slrd(tiny_cfg, key):
    cfg = dataclasses.replace(
        tiny_cfg, elitekv=EliteKVConfig(enabled=True, elite_r=4,
                                        d_ck=32, d_cv=32, lrd="separate"))
    params, buffers = lm.init(key, cfg)
    B, S = 2, 16
    batch = make_inputs(cfg, B, S, "train", seed=6)
    assert _roundtrip(cfg, params, buffers, batch, B, S, 8) < 2e-5


def test_cache_holds_rotated_keys(tiny_elite_cfg, tiny_elite_model):
    """The paper's systems claim: cached elite keys are post-RoPE (never
    re-rotated at decode).  Verify cache == rotate(k_e) explicitly."""
    from repro.core import elite_attention, rope as rope_lib
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, S = 1, 8
    batch = make_inputs(cfg, B, S, "train", seed=8)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache = lm.apply_prefill(params, buffers, cfg, batch, cache)
    # recompute expected rotated k_e for layer 0
    h = params["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    from repro.models.layers import rmsnorm
    p0 = jax.tree.map(lambda t: t[0], params["blocks"]["p0"])
    b0 = jax.tree.map(lambda t: t[0], buffers["blocks"]["p0"])
    hn = rmsnorm(p0["attn_norm"], h, cfg.norm_eps)
    k_e = jnp.einsum("bsd,dhe->bshe", hn, p0["attn"]["wk_e"])
    k_e = rope_lib.apply_elite_rope(k_e, jnp.arange(S), b0["elite_freqs"])
    got = cache["blocks"]["p0"]["k_e"][0, :, :S]
    np.testing.assert_allclose(np.asarray(got), np.asarray(k_e), atol=1e-5)


def test_elite_grad_flows(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    batch = make_inputs(tiny_elite_cfg, 2, 12, "train", seed=2)

    def loss(p):
        return lm.loss_fn(p, buffers, tiny_elite_cfg, batch)[0]

    g = jax.grad(loss)(params)
    leaves = {k: float(jnp.max(jnp.abs(v)))
              for k, v in jax.tree_util.tree_leaves_with_path(g)
              for k in ["/".join(str(getattr(x, 'key', x)) for x in k)][:1]}
    attn_g = [float(jnp.max(jnp.abs(v))) for path, v in
              jax.tree_util.tree_leaves_with_path(g)
              if "a_kv" in str(path) or "bk" in str(path) or "wk_e" in str(path)]
    assert all(x > 0 for x in attn_g), "no gradient through EliteKV params"


def test_full_rank_all_elite_equals_baseline(tiny_cfg, tiny_model, key):
    """r = C (all chunks rotated) + full-rank J-LRD ⇒ exactly the baseline."""
    from repro.core import convert
    params, buffers = tiny_model
    cfg = tiny_cfg
    C = cfg.head_dim // 2
    sets = {li: jnp.tile(jnp.arange(C, dtype=jnp.int32)[None], (cfg.n_kv_heads, 1))
            for li in range(cfg.num_layers)}
    ek = EliteKVConfig(enabled=True, elite_r=C,
                       d_ckv=min(cfg.n_kv_heads * cfg.head_dim, cfg.d_model))
    ep, eb, ecfg = convert.convert_model(params, buffers, cfg, sets, ek)
    batch = make_inputs(cfg, 2, 16, "train", seed=4)
    l0, _ = lm.apply_train(params, buffers, cfg, batch)
    l1, _ = lm.apply_train(ep, eb, ecfg, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=5e-5)
