"""Sharding rules + multi-device numerics (subprocess with virtual devices —
the main test process must keep seeing exactly 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.distributed import sharding as shd


def test_main_process_single_device():
    assert len(jax.devices()) == 1


def test_pad_cfg_for_tp():
    arctic = get_config("arctic_480b")
    p = shd.pad_cfg_for_tp(arctic, 16)
    assert p.n_heads == 64 and p.n_kv_heads == 8 and p.q_group == 8
    assert p.head_dim == arctic.head_dim
    mini = shd.pad_cfg_for_tp(get_config("minicpm_2b"), 16)
    assert mini.n_heads % 16 == 0 and mini.q_group == 1
    yi = shd.pad_cfg_for_tp(get_config("yi_6b"), 16)
    assert yi.n_heads == 32  # already divisible → unchanged


def test_param_specs_divisibility():
    """Every sharded dim must divide its mesh axis (else GSPMD pads/errors)."""
    from repro.models import lm

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("pod", "data", "model")

    plan = shd.MeshPlan(mesh=FakeMesh(), dp_axes=("pod", "data"))
    for arch in ("yi_6b", "qwen3_moe_235b", "jamba_v0_1_52b", "arctic_480b",
                 "falcon_mamba_7b", "minicpm_2b", "musicgen_large"):
        cfg = shd.pad_cfg_for_tp(get_config(arch), 16)
        pshapes, _ = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
        specs = shd.param_pspecs(pshapes, cfg, plan)

        def check(path, leaf, spec):
            for dim, s in zip(leaf.shape, tuple(spec)):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                n = 1
                for a in axes:
                    n *= plan.mesh.shape[a]
                assert dim % n == 0, (arch, path, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), pshapes, specs,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, type(None)))


def test_cell_applicability():
    from repro.configs import cell_applicable
    for arch, shape, expect in [
        ("yi_6b", "long_500k", False),
        ("falcon_mamba_7b", "long_500k", True),
        ("jamba_v0_1_52b", "long_500k", True),
        ("yi_6b", "train_4k", True),
    ]:
        ok, _ = cell_applicable(get_config(arch), SHAPES[shape])
        assert ok == expect, (arch, shape)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config, make_inputs
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_debug_mesh
    from repro.models import lm
    from repro.runtime import train_loop

    cfg = get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=256,
                                               n_heads=4, n_kv_heads=2)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    batch = make_inputs(cfg, 4, 32, "train", seed=0)

    # single-device reference
    loss_ref, _ = lm.loss_fn(params, buffers, cfg, batch)

    mesh = make_debug_mesh((2, 4), ("data", "model"))
    plan = shd.plan_for_mesh(mesh)
    pspecs = shd.param_pspecs(params, cfg, plan)
    P = jax.sharding.PartitionSpec
    pshard = jax.tree.map(plan.named, pspecs, is_leaf=lambda x: isinstance(x, P))
    params_s = jax.tree.map(jax.device_put, params, pshard)
    constrain = shd.make_constrain(plan, cfg, 32, 4)
    loss_sharded, _ = jax.jit(
        lambda p, b: lm.loss_fn(p, buffers, cfg, b, constrain=constrain)
    )(params_s, batch)

    # sharded train step runs
    tc = train_loop.TrainConfig(lr=1e-3)
    step = train_loop.make_train_step(cfg, tc, mesh=mesh, constrain=constrain,
                                      data_axes=plan.dp_axes)
    opt = train_loop.init_opt_state(params_s, tc)
    p2, o2, m = jax.jit(step)(params_s, buffers, opt, batch)
    print(json.dumps({
        "ref": float(loss_ref), "sharded": float(loss_sharded),
        "train_loss": float(m["loss"]), "gnorm": float(m["grad_norm"]),
    }))
""")


@pytest.mark.slow
def test_sharded_loss_matches_single_device(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, cwd=os.getcwd(),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["sharded"] == pytest.approx(res["ref"], rel=1e-4)
    assert np.isfinite(res["train_loss"]) and np.isfinite(res["gnorm"])
