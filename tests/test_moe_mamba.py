"""MoE dispatch implementations + Mamba scan equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("qwen3_moe_235b").reduced(
        num_layers=2, d_model=32, n_experts=4, top_k=2, moe_dff=16)
    params = moe_lib.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    return cfg, params, x


def test_ragged_matches_dense(moe_setup):
    cfg, params, x = moe_setup
    yd, _ = moe_lib.apply_dense(params, cfg, x)
    yr, _ = moe_lib.apply_ragged(params, cfg, x)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yd), atol=1e-4, rtol=1e-4)


def test_aux_loss_uniform_router():
    """With a perfectly uniform router the Switch aux loss → 1 as E·(1/E·1/E)·E."""
    cfg = get_config("qwen3_moe_235b").reduced(
        num_layers=2, d_model=16, n_experts=4, top_k=2, moe_dff=8)
    params = moe_lib.init(jax.random.PRNGKey(0), cfg)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16))
    _, aux = moe_lib.apply_dense(params, cfg, x)
    # uniform probs → P_e = 1/E; f_e sums to k ⇒ aux = E·Σ (1/E)·f_e = k
    assert float(aux) == pytest.approx(cfg.top_k, rel=0.05)


def test_moe_grads_flow(moe_setup):
    cfg, params, x = moe_setup

    def loss(p):
        y, aux = moe_lib.apply_ragged(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("w_gate", "w_up", "w_down", "router"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name


# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mamba_setup():
    cfg = get_config("falcon_mamba_7b").reduced(num_layers=1, d_model=32)
    params = mamba_lib.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.5
    return cfg, params, x


def test_mamba_chunk_invariance(mamba_setup):
    """ssm output independent of chunk size (incl. ragged last chunk)."""
    cfg, params, x = mamba_setup
    outs = []
    for chunk in (4, 7, 24):
        c2 = dataclasses.replace(cfg, ssm_chunk=chunk)
        outs.append(np.asarray(mamba_lib.apply_full(params, c2, x)))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4, rtol=1e-4)


def test_mamba_unroll_matches_scan(mamba_setup):
    cfg, params, x = mamba_setup
    y_scan = mamba_lib.apply_full(params, dataclasses.replace(cfg, ssm_chunk=8), x)
    y_unroll = mamba_lib.apply_full(
        params, dataclasses.replace(cfg, ssm_chunk=8, ssm_unroll=True), x)
    np.testing.assert_allclose(np.asarray(y_unroll), np.asarray(y_scan),
                               atol=1e-5, rtol=1e-5)


def test_mamba_naive_recurrence_oracle(mamba_setup):
    """Chunked associative scan == token-by-token recurrence."""
    cfg, params, x = mamba_setup
    y_fast, (conv_s, h_fin) = mamba_lib.apply_full(params, cfg, x, return_state=True)
    state = mamba_lib.init_state(cfg, 2, dtype=jnp.float32)
    ys = []
    for t in range(x.shape[1]):
        y_t, state = mamba_lib.apply_decode(params, cfg, x[:, t:t + 1], state)
        ys.append(y_t)
    y_slow = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_slow),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(state["ssm"]),
                               atol=2e-4, rtol=2e-4)


def test_mamba_grads_flow(mamba_setup):
    cfg, params, x = mamba_setup
    g = jax.grad(lambda p: jnp.sum(mamba_lib.apply_full(p, cfg, x) ** 2))(params)
    for name in ("in_proj", "conv_w", "A_log", "dt_w", "out_proj", "D"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
