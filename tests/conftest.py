"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs to launch/dryrun.py only).  Multi-device
sharding tests spawn subprocesses with their own env."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import EliteKVConfig


def pytest_collection_modifyitems(config, items):
    """Order-independence audit: ``REPRO_TEST_SHUFFLE=<seed>`` shuffles the
    collected test order deterministically.  Works without pytest-randomly
    (absent from the bare container); a shuffled run must pass identically
    to the default order — any diff is a hidden inter-test dependency
    (shared fixture mutation, module state, cache leakage)."""
    seed = os.environ.get("REPRO_TEST_SHUFFLE")
    if seed:
        import random
        random.Random(int(seed)).shuffle(items)


@pytest.fixture(scope="session")
def stress_blocks():
    """Pool-size override for serving-scheduler tests.  The CI serving-stress
    job sets ``REPRO_SERVE_STRESS_BLOCKS`` to a deliberately tiny pool so the
    scheduler tests run under constant preemption pressure — the tests'
    token-identity assertions must hold regardless (preemption is invisible
    in the output stream).  Returns ``f(default) -> num_blocks``."""
    override = os.environ.get("REPRO_SERVE_STRESS_BLOCKS")
    return (lambda default: int(override)) if override else (lambda default: default)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_cfg():
    """2-layer llama-like GQA config, fp32."""
    return get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=128)


@pytest.fixture(scope="session")
def tiny_elite_cfg(tiny_cfg):
    return dataclasses.replace(
        tiny_cfg, elitekv=EliteKVConfig(enabled=True, elite_r=4, d_ckv=64))


@pytest.fixture(scope="session")
def tiny_model(tiny_cfg, key):
    from repro.models import lm
    params, buffers = lm.init(key, tiny_cfg)
    return params, buffers


@pytest.fixture(scope="session")
def tiny_elite_model(tiny_elite_cfg, key):
    from repro.models import lm
    params, buffers = lm.init(key, tiny_elite_cfg)
    return params, buffers
