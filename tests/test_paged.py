"""Paged compressed-cache serving: kernel parity + pool + scheduler.

Golden tier: the paged decode (Pallas interpret mode and the XLA gather
fallback) must match ``kernels/ref.py`` to fp32 tolerance on ragged batches —
including empty (length-0) lanes and exact block-boundary lengths — across
GQA group sizes and block sizes.  Scheduler tier: paged continuous batching
must produce token-identical output to the contiguous lockstep path, and
retired sequences' blocks must actually be recycled.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import BlockAllocator, OutOfBlocks, PagedKVPool
from repro.kernels import elite_decode as ed
from repro.kernels import ref
from repro.models import lm
from repro.runtime import serve_loop


def _paged_case(seed, B, nkv, G, r2, dc, bs, pool_blocks, mb, lengths):
    """Random pool + per-sequence block chains for the given lengths."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    nh = nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, nh, dc))
    k_pages = jax.random.normal(ks[2], (pool_blocks * bs, nkv, r2))
    c_pages = jax.random.normal(ks[3], (pool_blocks * bs, dc))
    # distinct random chains per sequence (disjoint, arbitrary order)
    perm = rng.permutation(pool_blocks)
    bt = np.zeros((B, mb), np.int32)
    used = 0
    for b, length in enumerate(lengths):
        n = -(-length // bs)
        assert used + n <= pool_blocks
        bt[b, :n] = perm[used:used + n]
        used += n
    return (q_e, q_lat, k_pages, c_pages, jnp.asarray(bt),
            jnp.asarray(np.asarray(lengths, np.int32)))


# ---------------------------------------------------------------------------
# golden parity: paged Pallas + XLA fallback vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nkv,G,dc,bs", [
    (2, 2, 32, 8),        # GQA
    (1, 4, 64, 16),       # MQA-like, bigger blocks
    (2, 1, 16, 4),        # MHA-like, tiny blocks
])
def test_paged_decode_vs_ref_ragged(nkv, G, dc, bs):
    """Ragged lengths: empty lane, mid-block, exact block boundary, full."""
    mb = 4
    lengths = [0, 1, bs, 2 * bs - 1, 3 * bs, mb * bs]
    B = len(lengths)
    case = _paged_case(0, B, nkv, G, 8, dc, bs, pool_blocks=32, mb=mb,
                       lengths=lengths)
    q_e, q_lat, k_pages, c_pages, bt, lens = case
    o_ref = ref.elite_decode_paged_ref(q_e, q_lat, k_pages, c_pages, c_pages,
                                       bt, lens, G, 0.2, bs)
    o_pal = ed.elite_decode_paged(q_e, q_lat, k_pages, c_pages, c_pages,
                                  bt, lens, G, 0.2, bs, interpret=True)
    o_xla = ed.elite_decode_paged_xla(q_e, q_lat, k_pages, c_pages, c_pages,
                                      bt, lens, G, 0.2, bs)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)
    # empty lane is exactly zero, not a uniform-softmax average
    assert float(jnp.max(jnp.abs(o_ref[0]))) == 0.0
    assert float(jnp.max(jnp.abs(o_pal[0]))) == 0.0


def test_paged_decode_separate_cv():
    """S-LRD: distinct c_k / c_v page streams."""
    nkv, G, r2, dc, bs, mb = 2, 2, 4, 32, 8, 3
    lengths = [bs + 3, 2 * bs]
    q_e, q_lat, k_pages, c_k, bt, lens = _paged_case(
        1, 2, nkv, G, r2, dc, bs, pool_blocks=16, mb=mb, lengths=lengths)
    c_v = jax.random.normal(jax.random.PRNGKey(99), c_k.shape)
    o_ref = ref.elite_decode_paged_ref(q_e, q_lat, k_pages, c_k, c_v,
                                       bt, lens, G, 0.3, bs)
    o_pal = ed.elite_decode_paged(q_e, q_lat, k_pages, c_k, c_v,
                                  bt, lens, G, 0.3, bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)


def test_paged_matches_contiguous_kernel():
    """A paged layout whose chain is the identity must equal the contiguous
    dense kernel on the same data — the layouts describe the same cache."""
    nkv, G, r2, dc, bs = 2, 2, 8, 32, 8
    S = 4 * bs
    lengths = [S - 3, bs]
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, nh = 2, nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, nh, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c = jax.random.normal(ks[3], (B, S, dc))
    lens = jnp.asarray(lengths, jnp.int32)
    o_dense = ed.elite_decode(q_e, q_lat, k_e, c, c, lens, G, 0.2,
                              block_s=bs, interpret=True)
    # lay each sequence's cache out in its own pages, identity chains
    nb = S // bs
    k_pages = k_e.reshape(B * S, nkv, r2)
    c_pages = c.reshape(B * S, dc)
    bt = jnp.asarray([[b * nb + i for i in range(nb)] for b in range(B)],
                     jnp.int32)
    o_paged = ed.elite_decode_paged(q_e, q_lat, k_pages, c_pages, c_pages,
                                    bt, lens, G, 0.2, bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=3e-5, rtol=3e-5)


def test_dense_decode_length_zero():
    """The contiguous kernel and oracle agree on empty lanes too."""
    nkv, G, r2, dc, S = 2, 2, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, nh = 2, nkv * G
    q_e = jax.random.normal(ks[0], (B, nh, r2))
    q_lat = jax.random.normal(ks[1], (B, nh, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c = jax.random.normal(ks[3], (B, S, dc))
    lens = jnp.asarray([0, S // 2], jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c, c, lens, G, 0.25,
                          block_s=8, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c, c, lens, G, 0.25)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
    assert float(jnp.max(jnp.abs(o_r[0]))) == 0.0


# ---------------------------------------------------------------------------
# pool bookkeeping
# ---------------------------------------------------------------------------

def test_block_allocator_exhaustion_and_reuse():
    a = BlockAllocator(4)
    got = a.alloc(3)
    assert a.num_free == 1 and a.high_water == 3
    with pytest.raises(OutOfBlocks):
        a.alloc(2)
    a.free(got[:2])
    assert a.num_free == 3
    again = a.alloc(2)
    assert set(again) <= set(got[:2])      # freed blocks come back first
    assert a.high_water == 3               # peak unchanged by churn


def test_pool_accounting(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    pool.ensure_capacity(0, 6)             # 2 blocks
    pool.ensure_capacity(1, 4)             # 1 block
    s = pool.stats()
    assert s.blocks_in_use == 3 and s.live_tokens == 10
    assert s.allocated_tokens == 12        # internal fragmentation visible
    assert s.live_bytes < s.allocated_bytes
    fpt = pool.floats_per_token()
    assert s.live_bytes == 10 * fpt * 4    # fp32 pool
    pool.free_seq(0)
    assert pool.stats().blocks_in_use == 1
    pool.reset()
    assert pool.stats().blocks_in_use == 0 and pool.length(1) == 0


def test_pool_slot_mapping_chain_order(tiny_elite_cfg):
    pool = PagedKVPool(tiny_elite_cfg, num_blocks=8, block_size=4)
    pool.ensure_capacity(7, 9)             # 3 blocks
    table = pool.block_table(7)
    sm = pool.slot_mapping([7, None], [5, 0])
    assert sm[0] == table[1] * 4 + 1       # position 5 → block 1, offset 1
    assert sm[1] == pool.oob_slot          # inactive lane → sentinel
    pm = pool.prefill_slot_mapping(7, 0, 9, pad_to=12)
    assert pm[8] == table[2] * 4 and (pm[9:] == pool.oob_slot).all()


# ---------------------------------------------------------------------------
# scheduler: paged == contiguous, blocks recycle
# ---------------------------------------------------------------------------

def test_scheduler_matches_contiguous_generation(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, Sp, new = 3, 9, 6
    prompts = jax.random.randint(jax.random.PRNGKey(11), (B, Sp), 0,
                                 cfg.vocab_size, jnp.int32)
    out_dense, _ = serve_loop.generate(params, buffers, cfg, prompts, new)
    out_paged, report = serve_loop.generate_paged(params, buffers, cfg,
                                                  prompts, new)
    np.testing.assert_array_equal(out_dense, out_paged)
    assert report.completed == B
    assert report.decoded_tokens == B * new


def test_scheduler_ragged_stream_reuses_blocks(tiny_elite_cfg, tiny_elite_model):
    """Mixed-length staggered workload: drains fully, peak pool usage stays
    below the naive sum of per-request worst cases, and every block returns."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    scfg = serve_loop.SchedulerConfig(max_slots=2, block_size=4,
                                      num_blocks=48, max_len=32,
                                      prefill_bucket=4)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    rng = np.random.default_rng(2)
    reqs = [serve_loop.Request(
        uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                   int(rng.integers(3, 12))).astype(np.int32),
        max_new_tokens=int(rng.integers(3, 10)), arrival=i * 1.0)
        for i in range(6)]
    report = sched.run(reqs)
    assert report.completed == 6
    assert {r.finish_reason for r in sched.finished} <= {"eos", "budget"}
    # block reuse: the acceptance quantity — peak < Σ worst-case
    assert report.pool_high_water_blocks < report.naive_blocks
    assert sched.pool.allocator.num_free == scfg.num_blocks  # all recycled
    # per-request latency metrics exist and are ordered
    assert report.ttft_wall_p95_ms >= report.ttft_wall_p50_ms
    assert report.step_ms_p95 >= report.step_ms_p50


def test_freed_blocks_are_physically_reused(tiny_elite_cfg, tiny_elite_model):
    """With one slot, request B must be served out of the exact physical
    blocks request A returned."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    scfg = serve_loop.SchedulerConfig(max_slots=1, block_size=4,
                                      num_blocks=6, max_len=16,
                                      prefill_bucket=4)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    a = serve_loop.Request(uid=0, prompt=prompt, max_new_tokens=4)
    b = serve_loop.Request(uid=1, prompt=prompt.copy(), max_new_tokens=4)
    sched.submit(a)
    sched.submit(b)
    tables = {}
    for _ in range(200):
        alive = sched.step()
        for s in sched.slots:
            if s is not None:
                tables[s.uid] = sched.pool.block_table(s.uid)
        if not alive:
            break
    assert len(sched.finished) == 2
    assert set(tables[1]) & set(tables[0]), (tables, "no physical block reuse")
    # identical prompts with one slot ⇒ identical greedy continuations
    assert sched.finished[0].generated == sched.finished[1].generated


def test_scheduler_eos_retires_early(tiny_elite_cfg, tiny_elite_model):
    """Forcing eos_id to the model's first greedy token retires requests after
    one token and recycles their blocks for the queue."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    prompts = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0,
                                 cfg.vocab_size, jnp.int32)
    out, _ = serve_loop.generate(params, buffers, cfg, prompts, 1)
    eos = int(out[0, 0])
    scfg = serve_loop.SchedulerConfig(max_slots=1, block_size=4, num_blocks=8,
                                      max_len=16, prefill_bucket=8, eos_id=eos)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    req = serve_loop.Request(uid=0, prompt=np.asarray(prompts[0]),
                             max_new_tokens=8)
    report = sched.run([req])
    assert report.completed == 1
    assert sched.finished[0].finish_reason == "eos"
    assert len(sched.finished[0].generated) == 1
    assert sched.pool.allocator.num_free == scfg.num_blocks


def test_paged_prefill_writes_only_real_tokens(tiny_elite_cfg, tiny_elite_model):
    """Prompt padding lands on the sentinel slot and is dropped — pages
    outside the sequence's chain stay zero."""
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    pool = PagedKVPool(cfg, num_blocks=8, block_size=4)
    sp, pad = 5, 8
    pool.ensure_capacity(0, sp)
    tokens = np.zeros((1, pad), np.int32)
    tokens[0, :sp] = np.arange(sp) % cfg.vocab_size
    sm = pool.prefill_slot_mapping(0, 0, sp, pad)[None]
    _, pages = lm.apply_prefill_paged(params, buffers, cfg,
                                      {"tokens": jnp.asarray(tokens)},
                                      pool.pages, jnp.asarray(sm))
    owned = set()
    for blk in pool.block_table(0):
        owned.update(range(blk * 4, blk * 4 + 4))
    k_e = np.asarray(pages["p0"]["k_e"][0])     # layer 0 stream [n_slots,...]
    unowned = np.setdiff1d(np.arange(k_e.shape[0]), sorted(owned))
    assert np.all(k_e[unowned] == 0.0)
    # the sp real tokens did land
    live = pool.slot_mapping([0] * sp, list(range(sp)))
    assert np.all(np.abs(k_e[live]).max(axis=(1, 2)) > 0)
