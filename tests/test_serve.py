"""Serving loop: greedy generation correctness + cache accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_inputs
from repro.core.cache import (attn_cache_floats_per_token, cache_ratio,
                              measured_cache_bytes, model_cache_floats_per_token)
from repro.models import lm
from repro.runtime import serve_loop


def test_generate_matches_manual_greedy(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, Sp, new = 2, 10, 6
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, Sp), 0,
                                 cfg.vocab_size, jnp.int32)
    out, stats = serve_loop.generate(params, buffers, cfg, prompts, new)
    assert out.shape == (B, new)
    assert stats.decoded_tokens == B * new

    # manual reference: rerun full forward over prompt+generated prefix
    toks = prompts
    for t in range(new):
        logits, _ = lm.apply_train(params, buffers, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), out[:, t])
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)


def test_cache_bytes_elite_vs_baseline(tiny_cfg, tiny_elite_cfg):
    """Measured cache ratio == the paper's 2rn_kv + d_ckv formula."""
    B, L = 2, 16
    base_cache = lm.init_cache(tiny_cfg, B, L, dtype=jnp.bfloat16)
    elite_cache = lm.init_cache(tiny_elite_cfg, B, L, dtype=jnp.bfloat16)
    mb = measured_cache_bytes(base_cache, B, L)
    me = measured_cache_bytes(elite_cache, B, L)
    want = cache_ratio(tiny_elite_cfg, tiny_cfg)
    got = me["attn_bytes"] / mb["attn_bytes"]
    assert got == pytest.approx(want, rel=1e-6)
    # and the formula itself
    e = tiny_elite_cfg.elitekv
    assert attn_cache_floats_per_token(tiny_elite_cfg) == \
        2 * e.elite_r * tiny_elite_cfg.n_kv_heads + e.d_ckv


def test_serve_driver_runs(capsys):
    from repro.launch import serve
    serve.main(["--arch", "tinyllama_1_1b", "--reduced", "--elitekv",
                "--batch", "2", "--prompt-len", "8", "--new-tokens", "4"])
    out = capsys.readouterr().out
    assert "ratio" in out


def test_train_driver_runs(capsys):
    from repro.launch import train as train_mod
    hist = train_mod.main(["--arch", "tinyllama_1_1b", "--reduced", "--steps", "3",
                           "--batch", "2", "--seq", "32", "--log-every", "1"])
    assert len(hist) >= 1
