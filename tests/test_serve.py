"""Serving loop: greedy generation correctness + cache accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_inputs
from repro.core.cache import (attn_cache_floats_per_token, cache_ratio,
                              measured_cache_bytes, model_cache_floats_per_token)
from repro.models import lm
from repro.runtime import serve_loop


@pytest.mark.slow
def test_generate_matches_manual_greedy(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    cfg = tiny_elite_cfg
    B, Sp, new = 2, 10, 6
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, Sp), 0,
                                 cfg.vocab_size, jnp.int32)
    out, stats = serve_loop.generate(params, buffers, cfg, prompts, new)
    assert out.shape == (B, new)
    assert stats.decoded_tokens == B * new

    # manual reference: rerun full forward over prompt+generated prefix
    toks = prompts
    for t in range(new):
        logits, _ = lm.apply_train(params, buffers, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), out[:, t])
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)


def test_cache_bytes_elite_vs_baseline(tiny_cfg, tiny_elite_cfg):
    """Measured cache ratio == the paper's 2rn_kv + d_ckv formula."""
    B, L = 2, 16
    base_cache = lm.init_cache(tiny_cfg, B, L, dtype=jnp.bfloat16)
    elite_cache = lm.init_cache(tiny_elite_cfg, B, L, dtype=jnp.bfloat16)
    mb = measured_cache_bytes(base_cache, B, L)
    me = measured_cache_bytes(elite_cache, B, L)
    want = cache_ratio(tiny_elite_cfg, tiny_cfg)
    got = me["attn_bytes"] / mb["attn_bytes"]
    assert got == pytest.approx(want, rel=1e-6)
    # and the formula itself
    e = tiny_elite_cfg.elitekv
    assert attn_cache_floats_per_token(tiny_elite_cfg) == \
        2 * e.elite_r * tiny_elite_cfg.n_kv_heads + e.d_ckv


def test_serve_driver_runs(capsys):
    from repro.launch import serve
    serve.main(["--arch", "tinyllama_1_1b", "--reduced", "--elitekv",
                "--batch", "2", "--prompt-len", "8", "--new-tokens", "4"])
    out = capsys.readouterr().out
    assert "ratio" in out


def test_train_driver_runs(capsys):
    from repro.launch import train as train_mod
    hist = train_mod.main(["--arch", "tinyllama_1_1b", "--reduced", "--steps", "3",
                           "--batch", "2", "--seq", "32", "--log-every", "1"])
    assert len(hist) >= 1


# ---------------------------------------------------------------------------
# per-step phase breakdown (docs/observability.md)
# ---------------------------------------------------------------------------

def _stream_run(params, buffers, cfg, speculate=0):
    rng = np.random.default_rng(9)
    reqs = [serve_loop.Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(6, 14))).astype(np.int32),
        max_new_tokens=6, arrival=i * 0.5) for i in range(3)]
    scfg = serve_loop.SchedulerConfig(
        max_slots=2, block_size=4, num_blocks=64, max_len=32,
        prefill_bucket=4, prefill_chunk_tokens=4, speculate_k=speculate)
    sched = serve_loop.Scheduler(params, buffers, cfg, scfg)
    return sched.run(reqs)


def test_phase_breakdown_plain_decode(tiny_elite_cfg, tiny_elite_model):
    """Plain decode: phase keys are exactly PHASES, the phases that ran are
    positive, speculative phases are exactly zero, and the breakdown sums to
    the measured step wall time (the "other" residual closes the gap)."""
    rep = _stream_run(*tiny_elite_model, tiny_elite_cfg)
    assert set(rep.phase_ms) == set(serve_loop.PHASES)
    for phase in ("prefill", "decode", "sample"):
        assert rep.phase_ms[phase] > 0.0, phase
    for phase in ("draft", "verify", "accept"):
        assert rep.phase_ms[phase] == 0.0, phase   # never ran ⇒ exactly 0
    assert rep.phase_ms["swap"] == 0.0             # ample pool: no eviction
    total = rep.step_wall_ms_total
    assert total > 0.0
    assert abs(sum(rep.phase_ms.values()) - total) <= 0.02 * total + 1.0
    assert rep.phase_ms["other"] >= 0.0            # residual never negative
    table = rep.phase_table()
    assert "decode=" in table and "draft=" not in table


def test_sample_tokens_temp0_is_exact_argmax():
    """``temps[i] <= 0`` must take the argmax path STRUCTURALLY: a greedy
    lane in a mixed batch never routes through the temperature division, so
    its token is bitwise argmax — not softmax-at-clamped-temperature — even
    when adjacent logits differ by less than the 1e-6 clamp would resolve."""
    rng = np.random.default_rng(0)
    B, V = 6, 64
    logits = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    # near-ties: a clamped-temperature softmax draw could pick either one
    logits = logits.at[:, 1].set(logits[:, 0] + 1e-7)
    temps = jnp.asarray([0.0, 0.8, -1.0, 0.0, 1.3, 0.0], jnp.float32)
    top_ps = jnp.full((B,), 0.9, jnp.float32)
    seeds = jnp.arange(B, dtype=jnp.int32)
    counts = jnp.arange(B, dtype=jnp.int32)
    got = np.asarray(serve_loop.sample_tokens(logits, temps, top_ps,
                                              seeds, counts))
    want = np.asarray(jnp.argmax(logits, axis=-1))
    greedy = np.asarray(temps) <= 0.0
    np.testing.assert_array_equal(got[greedy], want[greedy])
    # and an all-greedy batch is the full argmax vector
    got_all = np.asarray(serve_loop.sample_tokens(
        logits, jnp.zeros((B,), jnp.float32), top_ps, seeds, counts))
    np.testing.assert_array_equal(got_all, want)


def test_phase_breakdown_speculative(tiny_elite_cfg, tiny_elite_model):
    """Speculative decode routes steps through draft/verify/accept instead
    of the plain decode phase; the sum invariant must still hold."""
    rep = _stream_run(*tiny_elite_model, tiny_elite_cfg, speculate=2)
    for phase in ("draft", "verify", "accept"):
        assert rep.phase_ms[phase] > 0.0, phase
    assert rep.phase_ms["decode"] == 0.0           # no plain decode steps ran
    total = rep.step_wall_ms_total
    assert abs(sum(rep.phase_ms.values()) - total) <= 0.02 * total + 1.0
