"""Low-rank decomposition: exactness, J-vs-S storage formulas (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lrd


@pytest.fixture(scope="module")
def mats():
    key = jax.random.PRNGKey(0)
    d, nkv, d_nope, dh = 64, 2, 12, 16
    wk = jax.random.normal(key, (d, nkv, d_nope)) / 8
    wv = jax.random.normal(jax.random.PRNGKey(1), (d, nkv, dh)) / 8
    return wk, wv


def test_svd_full_rank_exact(mats):
    wk, _ = mats
    W = np.asarray(wk).reshape(64, -1)
    A, B = lrd.svd_lowrank(W, min(W.shape))
    np.testing.assert_allclose(A @ B, W, atol=1e-5)


def test_jlrd_shapes_and_full_rank(mats):
    wk, wv = mats
    full = min(64, 2 * 12 + 2 * 16)
    a, bk, bv = lrd.jlrd(wk, wv, full)
    assert a.shape == (64, full)
    assert bk.shape == (full, 2, 12)
    assert bv.shape == (full, 2, 16)
    rk = np.einsum("dc,chn->dhn", np.asarray(a), np.asarray(bk))
    rv = np.einsum("dc,chn->dhn", np.asarray(a), np.asarray(bv))
    np.testing.assert_allclose(rk, np.asarray(wk), atol=1e-4)
    np.testing.assert_allclose(rv, np.asarray(wv), atol=1e-4)


def test_error_monotone_in_rank(mats):
    wk, wv = mats
    errs = []
    for r in (4, 8, 16, 32):
        a, bk, bv = lrd.jlrd(wk, wv, r)
        W = np.concatenate([np.asarray(wk).reshape(64, -1),
                            np.asarray(wv).reshape(64, -1)], 1)
        B = np.concatenate([np.asarray(bk).reshape(r, -1),
                            np.asarray(bv).reshape(r, -1)], 1)
        errs.append(lrd.reconstruction_error(W, a, B))
    assert all(e1 >= e2 - 1e-9 for e1, e2 in zip(errs, errs[1:]))


def test_optimal_slrd_split_beats_even(mats):
    wk, wv = mats
    budget = 24
    ck, cv = lrd.optimal_slrd_split(wk, wv, budget)
    assert ck + cv == budget

    def tail_err(ck_, cv_):
        sk = np.linalg.svd(np.asarray(wk).reshape(64, -1), compute_uv=False)
        sv = np.linalg.svd(np.asarray(wv).reshape(64, -1), compute_uv=False)
        return np.sum(sk[ck_:] ** 2) + np.sum(sv[cv_:] ** 2)

    assert tail_err(ck, cv) <= tail_err(budget // 2, budget - budget // 2) + 1e-9


def test_storage_formulas_match_param_count():
    """Model-level parameter accounting == paper's closed forms."""
    from repro.configs import get_config
    import dataclasses
    from repro.configs.base import EliteKVConfig
    from repro.models import lm

    cfg = get_config("tinyllama_1_1b").reduced(num_layers=1, vocab_size=128)
    for lrd_kind in ("joint", "separate"):
        e = EliteKVConfig(enabled=True, elite_r=4, d_ckv=48, d_ck=24, d_cv=24,
                          lrd=lrd_kind)
        ecfg = dataclasses.replace(cfg, elitekv=e)
        params, _ = lm.init(jax.random.PRNGKey(0), ecfg)
        attn = params["blocks"]["p0"]["attn"]
        got = sum(x.size for x in jax.tree.leaves(attn))
        d, dh, nh, nkv = ecfg.d_model, ecfg.head_dim, ecfg.n_heads, ecfg.n_kv_heads
        r = e.elite_r
        rot = d * 2 * r * nkv
        if lrd_kind == "joint":
            expect = (d * nh * dh + rot + nh * dh * d
                      + e.d_ckv * (d + nkv * (dh - 2 * r) + nkv * dh))
        else:
            expect = (d * nh * dh + rot + nh * dh * d
                      + e.d_ck * (d + nkv * (dh - 2 * r))
                      + e.d_cv * (d + nkv * dh))
        assert got == expect, (lrd_kind, got, expect)


def test_cache_formula(tiny_elite_cfg):
    """Cache/token/layer == 2·r·n_kv + d_ckv (paper §3.2)."""
    e = tiny_elite_cfg.elitekv
    got = e.cache_per_token_per_layer(tiny_elite_cfg.n_kv_heads,
                                      tiny_elite_cfg.head_dim)
    assert got == 2 * e.elite_r * tiny_elite_cfg.n_kv_heads + e.d_ckv
