"""Checkpointing (atomic, prune, elastic restore) and fault-tolerance runner."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault import (FaultTolerantRunner, HeartbeatMonitor,
                                 InjectedFault, StragglerPolicy)


@pytest.fixture
def state():
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}
    opt = {"step": jnp.asarray(5), "m": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)}}
    return params, opt


def test_roundtrip(tmp_path, state):
    params, opt = state
    ck = Checkpointer(str(tmp_path))
    ck.save(params, opt, {"step": 10, "loss": 1.5})
    p2, o2, extra = ck.restore_latest()
    assert extra["step"] == 10 and extra["loss"] == 1.5
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(params["w"]))
    np.testing.assert_allclose(np.asarray(o2["m"]["w"]), 0.0)


def test_uncommitted_checkpoint_ignored(tmp_path, state):
    params, opt = state
    ck = Checkpointer(str(tmp_path))
    ck.save(params, opt, {"step": 1})
    # simulate crash mid-save at step 2: directory without _COMMITTED
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ck.committed_steps() == [1]
    _, _, extra = ck.restore_latest()
    assert extra["step"] == 1


def test_keep_last_prunes(tmp_path, state):
    params, opt = state
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        ck.save(params, opt, {"step": s})
    assert ck.committed_steps() == [3, 4]


def test_restore_with_structure(tmp_path, state):
    params, opt = state
    ck = Checkpointer(str(tmp_path))
    ck.save(params, opt, {"step": 7})
    like_p = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    like_o = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt)
    p2, o2, extra = ck.restore(7, like=(like_p, like_o))
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)


# ---------------------------------------------------------------------------

def test_fault_runner_recovers_exact_state(tmp_path):
    """Training interrupted by injected faults ends in the same state as an
    uninterrupted run (checkpoint/restart + deterministic data)."""

    def make(fault_steps):
        ck = {"state": None, "step": 0}
        faults = set(fault_steps)

        def step_fn(s, i):
            return s + (i + 1)  # deterministic accumulation

        def save_fn(s, i):
            ck["state"], ck["step"] = s, i

        def restore_fn():
            return None if ck["state"] is None else (ck["state"], ck["step"])

        def hook(i):
            if i in faults:
                faults.remove(i)
                raise InjectedFault(f"boom at {i}")

        return FaultTolerantRunner(step_fn, save_fn, restore_fn, ckpt_every=3,
                                   fault_hook=hook)

    clean, _ = make([]).run(0, 20)
    r = make([5, 11, 17])
    faulty, _ = r.run(0, 20)
    assert faulty == clean
    assert r.restarts == 3
    assert r.steps_replayed > 0  # replays are real, bounded by ckpt_every


def test_fault_runner_gives_up(tmp_path):
    def hook(i):
        raise InjectedFault("always")

    r = FaultTolerantRunner(lambda s, i: s, lambda s, i: None, lambda: None,
                            ckpt_every=1, max_restarts=3, fault_hook=hook)
    with pytest.raises(InjectedFault):
        r.run(0, 5)
    assert r.restarts == 4


def test_heartbeat_and_straggler():
    t = {"now": 0.0}
    mon = HeartbeatMonitor(hosts=4, deadline_s=10, clock=lambda: t["now"])
    for step in range(8):
        t["now"] += 1.0
        for h in range(4):
            if h == 3 and step >= 4:
                continue  # host 3 dies at step 4
            dur = 2.0 if h != 2 else 4.5  # host 2 is a straggler
            mon.beat(h, duration_s=dur)
    t["now"] += 12.0
    assert mon.dead_hosts() == [3] or set(mon.dead_hosts()) >= {3}
    mon.evict(3)
    assert 3 not in mon.alive_hosts
    strag = StragglerPolicy(threshold=1.5, min_obs=5).stragglers(mon)
    assert strag == [2]


def test_train_loop_restart_integration(tmp_path, tiny_cfg):
    """Real model: train 6 steps with ckpt_every=2, kill, resume → same loss
    as training 6 steps straight."""
    import dataclasses
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models import lm
    from repro.runtime import train_loop

    cfg = dataclasses.replace(tiny_cfg)
    tc = train_loop.TrainConfig(lr=1e-3)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)

    def data():
        return iter(TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                             seq_len=16, batch_size=2, seed=1)))

    # uninterrupted
    p1, o1, h1 = train_loop.train(params, buffers, cfg, tc, data(), 6,
                                  log_every=1)
    # interrupted at step 4 (simulated by two runs sharing a checkpointer)
    ck = Checkpointer(str(tmp_path / "ck"))
    p2, o2, _ = train_loop.train(params, buffers, cfg, tc, data(), 4,
                                 checkpointer=ck, ckpt_every=2, log_every=1)
    p3, o3, h3 = train_loop.train(params, buffers, cfg, tc, data(), 6,
                                  checkpointer=ck, ckpt_every=2, log_every=1)
    np.testing.assert_allclose(float(h3[-1][1]), float(h1[-1][1]), rtol=1e-4)
