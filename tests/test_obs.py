"""Observability layer: tracer/metrics/timeline units, artifact validity,
and the golden invariant — instrumentation never changes what the scheduler
computes (traced and untraced runs are token-identical).

The exported artifacts are validated with the same ``tools/check_trace.py``
CI runs, loaded by path (tools/ is not a package), so the test suite and the
CI job can never drift on what "valid" means.
"""
import dataclasses
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer, to_chrome_trace,
                       write_chrome_trace)
from repro.runtime import serve_loop

_CHECK = Path(__file__).resolve().parent.parent / "tools" / "check_trace.py"


@pytest.fixture(scope="module")
def check_trace_mod():
    spec = importlib.util.spec_from_file_location("check_trace", _CHECK)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------

def test_tracer_ring_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert [e.name for e in evs] == ["e6", "e7", "e8", "e9"]
    assert tr.emitted == 10 and tr.dropped == 6
    assert [e.name for e in tr.last(2)] == ["e8", "e9"]


def test_tracer_span_times_and_nests_args():
    tr = Tracer()
    with tr.span("work", track="kernel", cat="kernel", shape="(2,3)"):
        pass
    (ev,) = tr.events()
    assert ev.ph == "X" and ev.dur >= 0.0 and ev.track == "kernel"
    assert ev.arg("shape") == "(2,3)"
    assert ev.args_dict() == {"shape": "(2,3)"}


def test_disabled_tracer_is_inert():
    before = NULL_TRACER.emitted
    NULL_TRACER.instant("x")
    with NULL_TRACER.span("y"):
        pass
    assert NULL_TRACER.emitted == before and NULL_TRACER.events() == []
    assert "disabled" in NULL_TRACER.format_tail(5)


def test_format_tail_mentions_recent_events():
    tr = Tracer()
    tr.instant("admit", uid=7)
    tail = tr.format_tail(5)
    assert "admit" in tail and "uid" in tail


# ---------------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------------

def test_chrome_export_structure_and_track_order():
    tr = Tracer()
    tr.begin("req0", track="slot0", cat="request")
    tr.instant("alloc", track="pool")
    tr.counter("pool_blocks_used", 3, track="pool")
    with tr.span("decode", track="scheduler", cat="phase"):
        pass
    tr.end("req0", track="slot0")
    doc = to_chrome_trace(tr)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    names = {e["args"]["name"]: e["tid"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    # pinned tracks take the low tids in fixed order; slots follow
    assert names["scheduler"] == 0 and names["kernel"] == 1 \
        and names["pool"] == 2 and names["slot0"] == 3
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "B", "E", "i", "C", "X"} <= phs


def test_chrome_export_passes_checker(tmp_path, check_trace_mod):
    tr = Tracer()
    with tr.span("prefill", track="scheduler", cat="phase", tokens=8):
        pass
    tr.counter("pool_blocks_used", np.int64(5), track="pool")  # numpy coerces
    path = write_chrome_trace(tmp_path / "t.json", tr)
    assert check_trace_mod.main([str(path)]) == 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_instruments_and_export(tmp_path, check_trace_mod):
    m = MetricsRegistry()
    m.counter("reqs_total", "requests").inc(3)
    m.gauge("slots").set(2)
    h = m.histogram("step_ms", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.cumulative() == [1, 2, 3]      # cumulative le-buckets, +Inf last
    # same name returns the same instrument; a kind clash is an error
    assert m.counter("reqs_total") is m.get("reqs_total")
    with pytest.raises(AssertionError):
        m.gauge("reqs_total")
    txt = m.to_prometheus()
    assert '# TYPE step_ms histogram' in txt
    assert 'step_ms_bucket{le="+Inf"} 3' in txt
    p = tmp_path / "m.prom"
    p.write_text(txt)
    t = tmp_path / "empty.json"
    t.write_text(json.dumps({"traceEvents": []}))
    assert check_trace_mod.main([str(t), "--metrics", str(p)]) == 0
    js = m.to_json()
    assert js["step_ms"]["count"] == 3 and js["reqs_total"]["value"] == 3


def test_counter_rejects_decrease():
    with pytest.raises(AssertionError):
        MetricsRegistry().counter("x").inc(-1)


# ---------------------------------------------------------------------------
# golden invariant: tracing never perturbs the run
# ---------------------------------------------------------------------------

def _reqs(cfg, n=4, temp=0.8):
    rng = np.random.default_rng(5)
    return [serve_loop.Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(6, 16))).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 9)), arrival=i * 0.7,
        temperature=temp, top_p=0.9, seed=31 + i) for i in range(n)]


def _scfg(num_blocks=10):
    return serve_loop.SchedulerConfig(
        max_slots=2, block_size=4, num_blocks=num_blocks, max_len=32,
        prefill_bucket=4, prefill_chunk_tokens=4, eviction="swap")


def test_traced_run_tokens_bit_identical(tiny_elite_cfg, tiny_elite_model,
                                         tmp_path, check_trace_mod,
                                         stress_blocks):
    """The acceptance gate: a fully traced + metered sampled run (tiny pool,
    preemption pressure) produces the exact token streams of an untraced
    run, and the artifacts it writes validate."""
    params, buffers = tiny_elite_model
    tr, metrics = Tracer(), MetricsRegistry()
    nb = stress_blocks(10)
    s1 = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(nb),
                              tracer=tr, metrics=metrics)
    rep1 = s1.run(_reqs(tiny_elite_cfg))
    s2 = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(nb))
    s2.run(_reqs(tiny_elite_cfg))
    assert {r.uid: list(r.generated) for r in s1.finished} == \
        {r.uid: list(r.generated) for r in s2.finished}

    assert rep1.trace_events == tr.emitted > 0
    lifecycle = [e.name for e in tr.events()]
    for name in ("submit", "admit", "first_token", "retire"):
        assert name in lifecycle
    tp = write_chrome_trace(tmp_path / "t.json", tr)
    mp = tmp_path / "m.prom"
    mp.write_text(metrics.to_prometheus())
    assert check_trace_mod.main([str(tp), "--metrics", str(mp)]) == 0
    assert metrics.get("serve_requests_completed_total").value == 4
    assert metrics.get("serve_tokens_decoded_total").value == \
        sum(len(r.generated) for r in s1.finished)


def test_untraced_scheduler_emits_nothing(tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    s = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(64))
    rep = s.run(_reqs(tiny_elite_cfg, n=2, temp=0.0))
    assert rep.trace_events == 0 and rep.trace_dropped == 0
    assert s.trace is NULL_TRACER and not s.trace.events()


# ---------------------------------------------------------------------------
# stuck-scheduler diagnostics (satellite bugfix)
# ---------------------------------------------------------------------------

def test_did_not_drain_error_carries_diagnostics(tiny_elite_cfg,
                                                 tiny_elite_model):
    params, buffers = tiny_elite_model
    tr = Tracer()
    s = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(64),
                             tracer=tr)
    with pytest.raises(RuntimeError) as ei:
        s.run(_reqs(tiny_elite_cfg, n=3, temp=0.0), max_steps=1)
    msg = str(ei.value)
    assert msg.startswith("scheduler did not drain in 1 steps")
    assert "uid=" in msg                    # per-request status lines
    assert "pool:" in msg                   # pool usage line
    assert "dropped from the ring" in msg   # tracer tail header attached
    assert "submit" in msg


def test_did_not_drain_without_tracer_still_reports_requests(
        tiny_elite_cfg, tiny_elite_model):
    params, buffers = tiny_elite_model
    s = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(64))
    with pytest.raises(RuntimeError) as ei:
        s.run(_reqs(tiny_elite_cfg, n=2, temp=0.0), max_steps=1)
    msg = str(ei.value)
    assert "uid=" in msg and "tracing disabled" in msg


# ---------------------------------------------------------------------------
# trace-summary CLI smoke
# ---------------------------------------------------------------------------

def test_trace_summary_cli(tiny_elite_cfg, tiny_elite_model, tmp_path,
                           capsys):
    from repro.launch import diagnose
    params, buffers = tiny_elite_model
    tr = Tracer()
    s = serve_loop.Scheduler(params, buffers, tiny_elite_cfg, _scfg(64),
                             tracer=tr)
    s.run(_reqs(tiny_elite_cfg, n=2, temp=0.0))
    path = write_chrome_trace(tmp_path / "t.json", tr)
    diagnose.main(["trace-summary", str(path)])
    out = capsys.readouterr().out
    assert "phase time" in out and "requests (2 submitted, 2 retired)" in out
    assert "pool occupancy" in out


# ---------------------------------------------------------------------------
# property: every alloc event pairs with exactly one free
# ---------------------------------------------------------------------------

try:                                        # property tier rides along when
    from hypothesis import given, settings, strategies as st   # CI installs
    _OPS = st.lists(                        # it; the unit tier above must
        st.tuples(                          # still run without it
            st.sampled_from(["grow", "free", "swap_out", "swap_in",
                             "truncate"]),
            st.integers(0, 3),              # seq id
            st.integers(1, 40)),            # target token count
        min_size=1, max_size=40)
    def _property(f):
        return settings(max_examples=25, deadline=None)(
            given(ops=_OPS, num_blocks=st.integers(2, 8))(f))
except ImportError:
    def _property(f):
        def skipped():
            pytest.skip("hypothesis not installed")
        skipped.__name__ = f.__name__
        skipped.__doc__ = f.__doc__
        return skipped


@_property
def test_every_alloc_event_has_one_free_event(ops, num_blocks):
    """Replay arbitrary pool op interleavings on a *traced* pool, then audit
    the event stream alone: each block id named by an ``alloc`` instant must
    be named by exactly one later ``free`` instant (release / truncate /
    swap-out eviction), never double-freed, never freed unallocated — the
    timeline is a faithful ledger of block ownership."""
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
    cfg = dc.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=64),
        elitekv=EliteKVConfig(enabled=True, elite_r=2, d_ckv=8))
    tr = Tracer()
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=4, tracer=tr)
    bm = BlockManager(pool)
    swapped = {}
    for op, sid, tokens in ops:
        try:
            if op == "grow":
                bm.grow(sid, tokens)
            elif op == "free":
                bm.release(sid)
            elif op == "swap_out":
                s = bm.preempt_swap_out(sid, pool.length(sid))
                if s is not None:
                    swapped[sid] = s
            elif op == "swap_in" and sid in swapped and not pool.block_table(sid):
                bm.swap_in(sid, swapped.pop(sid))
            elif op == "truncate":
                bm.truncate(sid, min(tokens, pool.length(sid)))
        except OutOfBlocks:
            pass
    for sid in list(pool._tables):
        bm.release(sid)

    live = set()
    for ev in tr.events():
        if ev.name == "alloc":
            blocks = set(ev.arg("blocks"))
            assert not blocks & live, "block allocated while still live"
            live |= blocks
        elif ev.name == "free":
            blocks = set(ev.arg("blocks"))
            assert blocks <= live, "freed a block no alloc event granted"
            live -= blocks
    assert not live, f"alloc events without a matching free: {live}"
