"""RoPE unit tests: relative-position identity, subset masks, per-head elite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rope as rope_lib


def test_chunk_freqs_descending():
    f = rope_lib.chunk_freqs(64, 10000.0)
    assert f.shape == (32,)
    assert np.all(np.diff(np.asarray(f)) < 0)
    assert float(f[0]) == 1.0


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    rot = rope_lib.apply_rope(x, jnp.arange(8), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(rot), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """⟨R(m)q, R(n)k⟩ depends only on m − n (paper Eq. 1)."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))

    def score(m, n):
        qm = rope_lib.apply_rope(q, jnp.array([m]), 100.0)
        kn = rope_lib.apply_rope(k, jnp.array([n]), 100.0)
        return float(jnp.sum(qm * kn))

    assert score(5, 3) == pytest.approx(score(12, 10), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(17, 10), rel=1e-4)


def test_subset_mask_identity_and_full():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.arange(8)
    none = rope_lib.apply_rope_subset(x, pos, 100.0, jnp.zeros(8, bool))
    np.testing.assert_allclose(np.asarray(none), np.asarray(x), atol=1e-6)
    full = rope_lib.apply_rope_subset(x, pos, 100.0, jnp.ones(8, bool))
    ref = rope_lib.apply_rope(x, pos, 100.0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref), atol=1e-5)


def test_subset_per_head_masks():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 8))
    pos = jnp.arange(4)
    mask = jnp.array([[True, False, True, False],
                      [False, True, False, True]])
    out = rope_lib.apply_rope_subset(x, pos, 50.0, mask)
    # head 0 chunk 1 (dims 2:4) must be untouched
    np.testing.assert_allclose(np.asarray(out[:, :, 0, 2:4]),
                               np.asarray(x[:, :, 0, 2:4]), atol=1e-6)
    # head 1 chunk 0 (dims 0:2) untouched
    np.testing.assert_allclose(np.asarray(out[:, :, 1, 0:2]),
                               np.asarray(x[:, :, 1, 0:2]), atol=1e-6)


def test_elite_rope_matches_subset_after_permutation():
    """apply_elite_rope on permuted dims == apply_rope_subset on originals."""
    B, S, H, dh = 1, 6, 2, 16
    C = dh // 2
    r = 3
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, dh))
    pos = jnp.arange(S)
    theta = 200.0
    elite = jnp.array([[0, 5, 2], [7, 1, 4]], jnp.int32)
    freqs = rope_lib.chunk_freqs(dh, theta)[elite]            # [H, r]
    # permute elite dims first
    from repro.core.convert import _perm_for
    xs = []
    for h in range(H):
        perm = _perm_for(np.asarray(elite[h]), C)
        xs.append(np.asarray(x)[:, :, h, perm])
    xp = jnp.asarray(np.stack(xs, axis=2))
    rot_elite = rope_lib.apply_elite_rope(xp[..., :2 * r], pos, freqs)
    # reference: subset rope then permute
    mask = np.zeros((H, C), bool)
    for h in range(H):
        mask[h, np.asarray(elite[h])] = True
    ref_full = rope_lib.apply_rope_subset(x, pos, theta, jnp.asarray(mask))
    refs = []
    for h in range(H):
        perm = _perm_for(np.asarray(elite[h]), C)
        refs.append(np.asarray(ref_full)[:, :, h, perm[:2 * r]])
    ref = np.stack(refs, axis=2)
    np.testing.assert_allclose(np.asarray(rot_elite), ref, atol=1e-5)


def test_expand_kv_to_q():
    per_kv = jnp.arange(6).reshape(2, 3)
    out = rope_lib.expand_kv_to_q(per_kv, 2)
    assert out.shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(out[3]))
