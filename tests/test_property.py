"""Hypothesis property tests on system invariants.

Skipped wholesale when ``hypothesis`` is absent (the CI image installs it;
bare containers must still *collect* this module without error).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import rope as rope_lib

_fast = settings(max_examples=20, deadline=None)


@given(dh=st.sampled_from([8, 16, 32, 64]),
       theta=st.floats(10.0, 1e6),
       seed=st.integers(0, 2**16))
@_fast
def test_rope_norm_preserved(dh, theta, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, 2, dh))
    rot = rope_lib.apply_rope(x, jnp.arange(4), theta)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(rot), axis=-1),
                               rtol=1e-4)


@given(m=st.integers(0, 500), n=st.integers(0, 500), delta=st.integers(0, 300),
       seed=st.integers(0, 100))
@_fast
def test_rope_relative_shift_invariance(m, n, delta, seed):
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 1, 1, 8))

    def s(a, b):
        qa = rope_lib.apply_rope(q, jnp.array([a]), 1000.0)
        kb = rope_lib.apply_rope(k, jnp.array([b]), 1000.0)
        return float(jnp.sum(qa * kb))

    assert s(m, n) == pytest.approx(s(m + delta, n + delta), rel=2e-3, abs=2e-4)


@given(nkv=st.sampled_from([1, 2, 4]), dh=st.sampled_from([16, 32, 64]),
       r=st.integers(1, 6), dc=st.sampled_from([16, 64, 256]))
@_fast
def test_cache_formula_invariant(nkv, dh, r, dc):
    """Formula == measured cache size for arbitrary valid EliteKV dims."""
    from repro.configs.base import EliteKVConfig
    from repro.core import elite_attention
    if 2 * r >= dh:
        return
    e = EliteKVConfig(enabled=True, elite_r=r, d_ckv=dc)
    cfg = dataclasses.replace(
        __import__("repro.configs", fromlist=["get_config"]).get_config(
            "tinyllama_1_1b").reduced(),
        n_kv_heads=nkv, n_heads=nkv * 2, d_head=dh, elitekv=e)
    cache = elite_attention.init_cache(cfg, batch=2, max_len=5, dtype=jnp.float32)
    floats = sum(x.size for x in jax.tree.leaves(cache)) // (2 * 5)
    assert floats == e.cache_per_token_per_layer(nkv, dh)


@given(seed=st.integers(0, 1000), k=st.integers(1, 3), E=st.sampled_from([4, 8]))
@_fast
def test_moe_gates_normalized(seed, k, E):
    from repro.models import moe as moe_lib
    from repro.configs import get_config
    cfg = get_config("qwen3_moe_235b").reduced(
        num_layers=2, d_model=16, n_experts=E, top_k=k, moe_dff=8)
    params = moe_lib.init(jax.random.PRNGKey(seed), cfg)
    xf = jax.random.normal(jax.random.PRNGKey(seed + 1), (10, 16))
    gates, idx, aux = moe_lib._route(params, cfg, xf)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-3)
    assert int(idx.max()) < E
    # top-k indices unique per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == k
    assert float(aux) >= 1.0 - 1e-5  # Switch aux lower bound at uniform


@given(seed=st.integers(0, 500), scale=st.floats(0.01, 10.0))
@_fast
def test_int8_quant_roundtrip_bound(seed, scale):
    from repro.optim.adamw import _dequant, _quant
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * scale
    q = _quant(x)
    assert q["q"].dtype == jnp.int8
    err = jnp.max(jnp.abs(_dequant(q) - x) / jnp.maximum(q["s"], 1e-20))
    assert float(err) <= 0.5 + 1e-3


@given(seed=st.integers(0, 500),
       magnitude=st.sampled_from([0.0, 1e-38, 1e-8, 1.0, 100.0, 1e18]),
       rows=st.integers(1, 6), dim=st.sampled_from([1, 8, 33]))
@_fast
def test_pool_quant_roundtrip_bound_and_positive_scales(seed, magnitude, rows,
                                                        dim):
    """Pool quantization (core/quant.py): scales are strictly positive for
    every row — including all-zero and denormal rows, where the absmax floor
    kicks in — and the round-trip error is bounded elementwise by half a
    quantization step (scale / 2)."""
    from repro.core import quant
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, dim)) * magnitude
    q, s = quant.quantize_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    s_np = np.asarray(s)
    assert s_np.shape == (rows,)
    assert np.all(s_np > 0.0), "scales must be strictly positive"
    assert int(np.max(np.abs(np.asarray(q, np.int32)))) <= quant.INT8_MAX
    err = np.abs(np.asarray(quant.dequantize(q, s))
                 - np.asarray(x, np.float32))
    assert np.all(err <= s_np[:, None] * (0.5 + 1e-6))


@given(length=st.integers(1, 20), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_int8_swap_roundtrip_byte_exact(length, seed):
    """Host swap of an int8 pool restores the int8 codes AND the f32 scale
    leaves byte-for-byte, even when the restored chain lands on different
    physical blocks — the property the preemption golden invariant
    (tests/test_quant.py) rides on."""
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.core.cache import BlockManager, PagedKVPool
    cfg = dc.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=64),
        elitekv=EliteKVConfig(enabled=True, elite_r=2, d_ckv=8))
    bs = 4
    pool = PagedKVPool(cfg, num_blocks=8, block_size=bs, dtype="int8")
    bm = BlockManager(pool)
    pool.ensure_capacity(0, length)
    rng = np.random.default_rng(seed)
    slots = jnp.asarray(pool.flat_slots(0, np.arange(length)))
    for p_key, layer in pool.pages.items():
        pool.pages[p_key] = {
            name: arr.at[:, slots].set(jnp.asarray(
                rng.integers(-127, 128,
                             (arr.shape[0], length) + arr.shape[2:])
                if arr.dtype == jnp.int8 else
                rng.uniform(1e-6, 2.0,
                            (arr.shape[0], length) + arr.shape[2:]),
                arr.dtype))
            for name, arr in layer.items()}

    def live(table):
        flat = [b * bs + i for b in table for i in range(bs)][:length]
        return {p: {n: np.asarray(a)[:, flat].copy()
                    for n, a in layer.items()}
                for p, layer in pool.pages.items()}

    before = live(pool.block_table(0))
    old_table = pool.block_table(0)
    swapped = bm.preempt_swap_out(0, length)
    assert any(a.dtype == np.int8 for s_ in swapped.streams.values()
               for a in s_.values())
    pool.ensure_capacity(99, 1)            # force a different chain
    bm.swap_in(0, swapped)
    if len(old_table) > 0:
        assert pool.block_table(0) != old_table
    after = live(pool.block_table(0))
    for p in before:
        for n in before[p]:
            assert before[p][n].dtype == after[p][n].dtype
            np.testing.assert_array_equal(before[p][n], after[p][n])


@given(chunk=st.integers(1, 24), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_ssm_scan_chunk_invariance(chunk, seed):
    from repro.models import mamba as mamba_lib
    B, S, di, N = 1, 12, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    xs = jax.random.normal(ks[1], (B, S, di))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)))
    D = jnp.ones(di)
    y1, h1 = mamba_lib.ssm_scan(dt, xs, Bm, Cm, A, D, chunk=chunk)
    y2, h2 = mamba_lib.ssm_scan(dt, xs, Bm, Cm, A, D, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 200), V=st.sampled_from([3, 4, 6]),
       temp=st.floats(0.3, 2.0), top_p=st.floats(0.3, 1.0))
@settings(max_examples=8, deadline=None)
def test_rejection_sampling_preserves_target_distribution(seed, V, temp, top_p):
    """The speculative accept-or-resample rule is distribution-preserving:
    for arbitrary target/draft logits, the emitted token (accepted draft, or
    the residual-corrected token on rejection) is distributed exactly as the
    target nucleus distribution — and never lands outside its nucleus."""
    from repro.runtime.serve_loop import (nucleus_probs, residual_sample,
                                          speculative_accept)
    rng = np.random.default_rng(seed)
    tgt_logits = rng.normal(size=V) * 2.0
    drf_logits = rng.normal(size=V) * 2.0
    p = nucleus_probs(tgt_logits, temp, top_p)
    q = nucleus_probs(drf_logits, temp, top_p)
    np.testing.assert_allclose(p.sum(), 1.0, atol=1e-9)
    assert p.min() >= 0.0 and (p > 0).any()

    N = 4000
    # vectorized trial loop: draft proposals ~ q, then accept/correct
    xs = rng.choice(V, size=N, p=q / q.sum())
    us = rng.random(N)
    rs = rng.random(N)
    out = np.array([x if speculative_accept(x, p, q, u)
                    else residual_sample(p, q, r)
                    for x, u, r in zip(xs, us, rs)])
    # never outside the target nucleus
    assert np.all(p[out] > 0.0)
    emp = np.bincount(out, minlength=V) / N
    # total-variation bound generous for N=4000, V<=6 (≈ 4.5 sigma)
    assert 0.5 * np.abs(emp - p).sum() < 0.06


@given(seed=st.integers(0, 500), temp=st.floats(0.2, 3.0),
       top_p=st.floats(0.1, 1.0))
@settings(max_examples=15, deadline=None)
def test_nucleus_probs_matches_sampler_support(seed, temp, top_p):
    """``nucleus_probs`` is the exact distribution ``sample_tokens`` draws
    from: its support equals the sampler's reachable set and a full-nucleus
    draw agrees with the softmax."""
    from repro.runtime import serve_loop
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=8) * 3.0
    p = serve_loop.nucleus_probs(logits, temp, top_p)
    # sampler draws many tokens; all must be inside the nucleus support
    draws = np.asarray(jax.vmap(
        lambda c: serve_loop.sample_tokens(
            jnp.asarray(logits)[None],
            jnp.asarray([temp], jnp.float32),
            jnp.asarray([top_p], jnp.float32),
            jnp.asarray([seed], jnp.int32),
            jnp.asarray([c], jnp.int32))[0])(jnp.arange(64)))
    assert np.all(p[draws] > 0.0)
    if top_p >= 1.0:                       # full nucleus: plain softmax
        sc = logits / max(temp, 1e-6)
        sm = np.exp(sc - sc.max()) / np.exp(sc - sc.max()).sum()
        np.testing.assert_allclose(p, sm, atol=1e-9)


_BM_OPS = st.lists(
    st.tuples(st.sampled_from(["grow", "free", "swap_out", "swap_in",
                               "truncate"]),
              st.integers(0, 3),            # seq id
              st.integers(1, 40)),          # target token count (grow/trunc)
    min_size=1, max_size=40)


@pytest.mark.parametrize("pool_dtype", ["float32", "int8"])
@given(ops=_BM_OPS, num_blocks=st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_block_manager_never_leaks_or_double_frees(ops, num_blocks,
                                                   pool_dtype):
    """Arbitrary alloc/free/preempt(swap)/truncate interleavings on a tiny
    pool keep the allocator exactly conserved: free + owned == capacity,
    chains stay disjoint, no block is ever double-freed or leaked — even
    when operations bounce off ``OutOfBlocks``.  ``truncate`` is the
    speculative verify-window rollback: it must return exactly the tail
    blocks the shorter chain no longer covers.  Runs against both the f32
    and the quantized int8 pool — block accounting must be dtype-blind."""
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
    cfg = dc.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=64),
        elitekv=EliteKVConfig(enabled=True, elite_r=2, d_ckv=8))
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=4,
                       dtype="int8" if pool_dtype == "int8" else jnp.float32)
    bm = BlockManager(pool)
    swapped = {}

    def check():
        alloc = pool.allocator
        assert alloc.num_free + alloc.num_used == num_blocks
        owned = [b for sid in list(pool._tables) for b in pool.block_table(sid)]
        assert len(owned) == len(set(owned)), "chains share a block"
        assert len(owned) == alloc.num_used, "leak or double-free"
        assert not set(owned) & set(alloc._free), "owned block on free list"

    for op, sid, tokens in ops:
        try:
            if op == "grow":
                bm.grow(sid, tokens)
            elif op == "free":
                bm.release(sid)
            elif op == "swap_out":
                s = bm.preempt_swap_out(sid, pool.length(sid))
                if s is not None:
                    swapped[sid] = s
            elif op == "swap_in" and sid in swapped and not pool.block_table(sid):
                bm.swap_in(sid, swapped.pop(sid))
            elif op == "truncate":
                bm.truncate(sid, min(tokens, pool.length(sid)))
        except OutOfBlocks:
            pass                            # valid outcome; state must stay sane
        check()
    for sid in list(pool._tables):
        bm.release(sid)
    assert pool.allocator.num_free == num_blocks


_PC_OPS = st.lists(
    st.tuples(st.sampled_from(["grow", "free", "swap_out", "swap_in",
                               "truncate", "lookup", "register", "write"]),
              st.integers(0, 3),            # seq id
              st.integers(1, 40)),          # token count / position source
    min_size=1, max_size=50)


@given(ops=_PC_OPS, num_blocks=st.integers(3, 10))
@settings(max_examples=25, deadline=None)
def test_block_manager_prefix_cache_conservation(ops, num_blocks):
    """Prefix-cache op-fuzz: arbitrary interleavings of growth, release,
    swap, truncate, cache lookup/registration and copy-on-write barriers
    keep the pool exactly conserved after EVERY op:

    * free list + distinct chain-referenced + LRU-retained == pool size
    * refcounts equal the number of chains referencing each block (no leak,
      no double-free, no phantom reference)
    * a block covered by a just-issued write barrier has refcount exactly 1
      and no live cache claim — no write is ever visible through another
      resident's chain
    * retained blocks are always cached, never on the free list, never in a
      chain; the hash map stays a bijection

    Every sequence presents the same token stream, so lookups genuinely
    collide and sharing pressure is maximal."""
    import collections as _c
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
    cfg = dc.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=64),
        elitekv=EliteKVConfig(enabled=True, elite_r=2, d_ckv=8))
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=4)
    bm = BlockManager(pool, prefix_cache=True)
    pc = bm.prefix
    swapped = {}
    stream = np.arange(64, dtype=np.int32) % 64   # shared by every sequence

    def check():
        alloc = pool.allocator
        counts = _c.Counter(b for sid in list(pool._tables)
                            for b in pool.block_table(sid))
        referenced = set(counts)
        retained = set(pc._lru)
        free = set(alloc._free)
        # partition: every block is exactly one of free/referenced/retained
        assert alloc.num_free + len(referenced) + len(retained) == num_blocks
        assert not referenced & retained and not referenced & free \
            and not retained & free
        assert dict(counts) == pool._refcount, "refcount drift"
        # retained ⊆ cached; hash map is a bijection
        assert retained <= set(pc._by_block)
        assert len(pc._by_hash) == len(pc._by_block)
        assert set(pc._by_hash.values()) == set(pc._by_block)

    for op, sid, tokens in ops:
        try:
            if op == "grow":
                bm.grow(sid, tokens)
            elif op == "free":
                bm.release(sid)
            elif op == "swap_out":
                s = bm.preempt_swap_out(sid, pool.length(sid))
                if s is not None:
                    swapped[sid] = s
            elif op == "swap_in" and sid in swapped \
                    and not pool.block_table(sid) and pool.length(sid) == 0:
                bm.swap_in(sid, swapped.pop(sid))
            elif op == "truncate":
                bm.truncate(sid, min(tokens, pool.length(sid)))
            elif op == "lookup" and not pool.block_table(sid) \
                    and pool.length(sid) == 0:
                bm.lookup_prefix(sid, stream[:tokens])
            elif op == "register":
                bm.register_prefix(sid, stream[:pool.length(sid)])
            elif op == "write" and pool.length(sid) > 0:
                length = pool.length(sid)
                start = tokens % length
                bm.prepare_write(sid, start, length)
                bs = pool.block_size
                table = pool.block_table(sid)
                for bi in range(start // bs, len(table)):
                    b = table[bi]
                    # write isolation: the barrier leaves every covered
                    # block exclusively owned and unclaimed
                    assert pool._refcount[b] == 1, "write into shared block"
                    assert not pc.is_cached(b), "write into cached block"
        except OutOfBlocks:
            pass                            # valid outcome; state must stay sane
        check()
    for sid in list(pool._tables):
        bm.release(sid)
    check()
    assert pool.allocator.num_free + pc.num_retained == num_blocks


_ROUTER_OPS = st.lists(
    st.tuples(st.sampled_from(["route", "admit", "retire", "preempt"]),
              st.integers(0, 3),            # replica index (mod n)
              st.integers(1, 12)),          # token count for admissions
    min_size=1, max_size=60)


@given(ops=_ROUTER_OPS, n=st.integers(2, 4), num_blocks=st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_router_admission_ledger_conservation(ops, n, num_blocks):
    """Router admission op-fuzz (runtime/router.py::ReplicaBoard): arbitrary
    route/admit/preempt/retire interleavings — with every admission backed by
    real block growth on that replica's own pool — keep the ledger exactly
    conserved after EVERY op:

    * sum(waiting) + sum(resident) == submitted - retired (board.check)
    * the board mirrors the model queues replica by replica
    * ``pick`` always returns a least-loaded replica (deterministic ties)
    * no replica's pool leaks: free + owned == capacity even when an
      admission bounces off ``OutOfBlocks`` and re-queues

    This is the same ledger the live Router reconciles against observed
    scheduler deltas each global step, so conservation here is conservation
    in production."""
    import collections as _c
    import dataclasses as dc
    from repro.configs import get_config
    from repro.configs.base import EliteKVConfig
    from repro.core.cache import BlockManager, OutOfBlocks, PagedKVPool
    from repro.runtime.router import ReplicaBoard
    cfg = dc.replace(
        get_config("tinyllama_1_1b").reduced(num_layers=2, vocab_size=64),
        elitekv=EliteKVConfig(enabled=True, elite_r=2, d_ckv=8))
    board = ReplicaBoard(n)
    pools = [PagedKVPool(cfg, num_blocks=num_blocks, block_size=4)
             for _ in range(n)]
    bms = [BlockManager(p) for p in pools]
    waiting = [_c.deque() for _ in range(n)]
    resident = [dict() for _ in range(n)]    # uid -> tokens held
    uid = 0

    def check():
        board.check()
        # regression: the imbalance gauge must be finite at EVERY point in a
        # run — before the first route (all replicas at zero) and while some
        # replicas have yet to see traffic (zero-routed used to yield inf)
        imb = board.imbalance()
        assert imb == imb and imb != float("inf"), imb
        assert imb >= 1.0, imb
        for j in range(n):
            assert board.waiting[j] == len(waiting[j])
            assert board.resident[j] == len(resident[j])
            alloc = pools[j].allocator
            assert alloc.num_free + alloc.num_used == num_blocks
            owned = [b for sid in list(pools[j]._tables)
                     for b in pools[j].block_table(sid)]
            assert len(owned) == len(set(owned)) == alloc.num_used

    for op, ridx, tokens in ops:
        i = ridx % n
        if op == "route":
            j = board.pick()
            assert board.load(j) == min(board.load(k) for k in range(n))
            board.route(j)
            waiting[j].append(uid)
            uid += 1
        elif op == "admit" and waiting[i]:
            u = waiting[i].popleft()
            try:
                bms[i].grow(u, tokens)
                board.admit(i)
                resident[i][u] = tokens
            except OutOfBlocks:
                bms[i].release(u)            # partial growth must roll back
                waiting[i].appendleft(u)     # still waiting, ledger untouched
        elif op == "retire" and resident[i]:
            u = next(iter(resident[i]))
            del resident[i][u]
            bms[i].release(u)
            board.retire(i)
        elif op == "preempt" and resident[i]:
            u = next(iter(resident[i]))
            del resident[i][u]
            bms[i].release(u)                # recompute-style full eviction
            board.preempt(i)
            waiting[i].append(u)
        check()

    # drain: admit-then-retire everything left; the ledger must land on zero
    for i in range(n):
        while waiting[i]:
            u = waiting[i].popleft()
            board.admit(i)
            board.retire(i)
        for u in list(resident[i]):
            del resident[i][u]
            bms[i].release(u)
            board.retire(i)
    check()
    assert sum(board.waiting) + sum(board.resident) == 0
    assert board.submitted == board.retired == uid
    assert all(p.allocator.num_free == num_blocks for p in pools)


def test_router_imbalance_zero_routed_regression():
    """A replica that never saw a request must not poison the imbalance
    metric: the gauge covers replicas WITH traffic (1.0 when even), never
    inf/NaN, and stays 1.0 on a completely idle board."""
    from repro.runtime.router import ReplicaBoard
    board = ReplicaBoard(3)
    assert board.imbalance() == 1.0          # idle board, no 0/0
    board.route(0)                           # replica 1 and 2 still at zero
    assert board.imbalance() == 1.0
    board.route(0)
    board.route(1)                           # routed == [2, 1, 0]
    assert board.imbalance() == 2.0          # max/min over active replicas
    board.route(2)
    assert board.imbalance() == 2.0          # [2, 1, 1]


@given(B=st.integers(1, 3), length=st.integers(1, 32), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_elite_decode_kernel_vs_oracle_property(B, length, seed):
    from repro.kernels import elite_decode as ed
    from repro.kernels import ref
    nkv, G, r2, dc, S = 2, 2, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q_e = jax.random.normal(ks[0], (B, nkv * G, r2))
    q_lat = jax.random.normal(ks[1], (B, nkv * G, dc))
    k_e = jax.random.normal(ks[2], (B, S, nkv, r2))
    c = jax.random.normal(ks[3], (B, S, dc))
    lengths = jnp.full((B,), min(length, S), jnp.int32)
    o_k = ed.elite_decode(q_e, q_lat, k_e, c, c, lengths, G, 0.25,
                          block_s=8, interpret=True)
    o_r = ref.elite_decode_ref(q_e, q_lat, k_e, c, c, lengths, G, 0.25)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=3e-5, rtol=3e-5)
