"""Conversion pipeline: surgery exactness, GQA pooling, dimension selection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, make_inputs
from repro.configs.base import EliteKVConfig
from repro.core import convert, ropelite
from repro.models import lm


def test_exact_rank_matches_partial_rope_reference(tiny_cfg, tiny_model):
    """Full-rank J-LRD conversion == baseline with RoPE restricted to the
    elite sets (the only difference EliteKV should introduce pre-truncation)."""
    params, buffers = tiny_model
    cfg = tiny_cfg
    batch = make_inputs(cfg, 2, 16, "train", seed=3)
    sets = ropelite.search_model(params, buffers, cfg, batch, r=4)
    full = cfg.n_kv_heads * (cfg.head_dim - 8) + cfg.n_kv_heads * cfg.head_dim
    ek = EliteKVConfig(enabled=True, elite_r=4, d_ckv=min(full, cfg.d_model))
    ep, eb, ecfg = convert.convert_model(params, buffers, cfg, sets, ek)

    # reference model: monkey-patch rope to subset via masks
    from repro.core import rope as rope_lib
    from repro.models import attention as att
    C = cfg.head_dim // 2

    orig = rope_lib.apply_rope
    masks = {}
    for li, idx in sets.items():
        m = np.zeros((cfg.n_kv_heads, C), bool)
        for h in range(cfg.n_kv_heads):
            m[h, np.asarray(idx[h])] = True
        masks[li] = jnp.asarray(m)

    # compute reference logits by manual per-layer forward with subset rope
    def ref_logits():
        h = params["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
        from repro.models.layers import mlp, rmsnorm, unembed
        pos = jnp.arange(h.shape[1])
        for li in range(cfg.num_layers):
            p = jax.tree.map(lambda t: t[li], params["blocks"]["p0"])
            hn = rmsnorm(p["attn_norm"], h, cfg.norm_eps)
            dt = h.dtype
            q = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wq"].astype(dt))
            k = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wk"].astype(dt))
            v = jnp.einsum("bsd,dhe->bshe", hn, p["attn"]["wv"].astype(dt))
            mq = jnp.repeat(masks[li], cfg.q_group, axis=0)
            q = rope_lib.apply_rope_subset(q, pos, cfg.rope_theta, mq)
            k = rope_lib.apply_rope_subset(k, pos, cfg.rope_theta, masks[li])
            o = att._attend(q, k, v, cfg.q_group, cfg.head_dim ** -0.5)
            h = h + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"].astype(dt))
            hn = rmsnorm(p["ffn_norm"], h, cfg.norm_eps)
            h = h + mlp(p["ffn"], hn)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return unembed(params["embed"], h) if cfg.tie_embeddings else \
            h.astype(jnp.float32) @ params["lm_head"]["w"]

    l_ref = ref_logits()
    l_elite, _ = lm.apply_train(ep, eb, ecfg, batch)
    V = cfg.vocab_size
    np.testing.assert_allclose(np.asarray(l_elite[..., :V]),
                               np.asarray(l_ref[..., :V]),
                               atol=1e-3, rtol=1e-3)


def test_gqa_pool_identity_when_groups_of_one(tiny_cfg, tiny_model):
    params, _ = tiny_model
    gp, gcfg = convert.to_gqa(params, tiny_cfg, tiny_cfg.n_kv_heads)
    np.testing.assert_allclose(
        np.asarray(gp["blocks"]["p0"]["attn"]["wk"]),
        np.asarray(params["blocks"]["p0"]["attn"]["wk"]))


def test_gqa_pool_reduces_and_runs(tiny_cfg, tiny_model):
    params, buffers = tiny_model
    gp, gcfg = convert.to_gqa(params, tiny_cfg, tiny_cfg.n_kv_heads // 2)
    assert gcfg.n_kv_heads == tiny_cfg.n_kv_heads // 2
    batch = make_inputs(gcfg, 2, 12, "train")
    loss, _ = lm.loss_fn(gp, buffers, gcfg, batch)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("ratio", [0.5, 0.25, 0.125])
def test_pick_dims_constraints(ratio):
    for arch in ("yi_6b", "llama2_7b", "musicgen_large", "qwen3_moe_235b"):
        cfg = get_config(arch)
        ek = convert.pick_dims(cfg, ratio)
        full = 2 * cfg.n_kv_heads * cfg.head_dim
        got = ek.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim) / full
        assert abs(got - ratio) < 0.13, (arch, got, ratio)
        assert 2 * ek.elite_r < cfg.head_dim
        # no-extra-parameter rule (paper App. C)
        d, dh, nkv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
        nope = nkv * (dh - 2 * ek.elite_r)
        new = d * 2 * ek.elite_r * nkv + d * ek.d_ckv + ek.d_ckv * (nope + nkv * dh)
        assert new <= d * dh * 2 * nkv


def test_end_to_end_pipeline(tiny_cfg, tiny_model):
    """search + convert + uptrain-one-step + decode — the paper's full flow."""
    params, buffers = tiny_model
    cfg = tiny_cfg
    batch = make_inputs(cfg, 2, 16, "train", seed=1)
    ek = EliteKVConfig(enabled=True, elite_r=4, d_ckv=48)
    ep, eb, ecfg = convert.elitekv_from_baseline(params, buffers, cfg, batch, ek)
    loss0, _ = lm.loss_fn(ep, eb, ecfg, batch)
    assert jnp.isfinite(loss0)
    g = jax.grad(lambda p: lm.loss_fn(p, eb, ecfg, batch)[0])(ep)
    ep2 = jax.tree.map(lambda p, gg: p - 1e-3 * gg, ep, g)
    loss1, _ = lm.loss_fn(ep2, eb, ecfg, batch)
    assert float(loss1) < float(loss0)
