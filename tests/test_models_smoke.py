"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step on CPU — output shapes
asserted, no NaNs.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, make_inputs
from repro.models import lm

ARCHS = list(list_archs())
#: archs whose reduced config still takes >8s for a train step on CPU
#: (--durations=15): their parametrized legs are deselectable via
#: -m "not slow" (ARCHS itself stays a plain string list — tests iterate it)
_SLOW_ARCHS = {"jamba_v0_1_52b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
               else a for a in ARCHS]


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = make_inputs(cfg, B, S, "train")
    logits, aux = lm.apply_train(params, buffers, cfg, batch, moe_impl="dense")
    S_txt = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    exp_len = S_txt + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, exp_len, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, metrics = lm.loss_fn(params, buffers, cfg, batch, moe_impl="dense")
    assert jnp.isfinite(loss)

    # one gradient step decreases nothing catastrophic (finite grads)
    g = jax.grad(lambda p: lm.loss_fn(p, buffers, cfg, batch, moe_impl="dense")[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
    assert jnp.isfinite(gn) and float(gn) > 0


@pytest.mark.parametrize("arch", [
    "yi_6b", "qwen3_moe_235b", "falcon_mamba_7b",
    pytest.param("jamba_v0_1_52b", marks=pytest.mark.slow), "musicgen_large"])
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.frontend == "audio":
        pre = make_inputs(cfg, B, 8, "prefill")
        step_in = {"frames": pre["frames"][:, :1]}
    else:
        pre = {"tokens": make_inputs(cfg, B, 8, "prefill")["tokens"]}
        step_in = {"tokens": pre["tokens"][:, :1]}
    logits, cache = lm.apply_prefill(params, buffers, cfg, pre, cache, moe_impl="dense")
    assert int(cache["index"]) == 8
    logits2, cache = lm.apply_decode(params, buffers, cfg, step_in, cache, moe_impl="dense")
    assert logits2.shape[1] == 1
    assert not bool(jnp.any(jnp.isnan(logits2)))
    assert int(cache["index"]) == 9


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_formula(arch):
    """Analytic param_count == actual initialized size (modulo vocab padding)."""
    cfg = get_config(arch).reduced()
    params, _ = lm.init(jax.random.PRNGKey(0), cfg)
    got = sum(x.size for x in jax.tree.leaves(params))
    pad = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
    n_vocab_mats = (0 if cfg.frontend == "audio" else 1) + (
        1 if (cfg.frontend == "audio" or not cfg.tie_embeddings) else 0)
    expect = cfg.param_count() + pad * n_vocab_mats
    assert got == expect, (got, expect, got - expect)


def test_elitekv_reduces_cache_all_attention_archs():
    from repro.core.convert import pick_dims
    for arch in ARCHS:
        cfg = get_config(arch)
        if cfg.n_attn_layers == 0:
            continue
        ek = pick_dims(cfg, 0.25)
        full = 2 * cfg.n_kv_heads * cfg.head_dim
        got = ek.cache_per_token_per_layer(cfg.n_kv_heads, cfg.head_dim)
        assert got <= 0.3 * full, (arch, got, full)
